"""Pure-jnp oracles for the AMS-Quant matmul kernels.

``ams_matmul_ref`` is the bit-exact reference the Pallas kernel is tested
against. ``ams_matmul_blocked`` is the XLA-path production fallback: a
K-blocked scan that never materializes the full dequantized weight (the
live set per step is one [bK, N] tile), which is what the dry-run lowers
when the Pallas kernel is unavailable on the target.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import code_to_value
from repro.core.packing import PackedWeight, unpack


def dequant_full(pw: PackedWeight, dtype=jnp.float32) -> jnp.ndarray:
    """[K, N] dequantized weight (scale applied)."""
    codes = unpack(pw)
    return (code_to_value(pw.layout.scheme.base, codes) * pw.scale).astype(dtype)


def ams_matmul_ref(x: jnp.ndarray, pw: PackedWeight) -> jnp.ndarray:
    """y = x @ DeQ(W), f32 accumulation. x: [B, K]."""
    w = dequant_full(pw, jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)


def _decode_codes(pw: PackedWeight, codes: jnp.ndarray) -> jnp.ndarray:
    return code_to_value(pw.layout.scheme.base, codes)


def ams_matmul_blocked(
    x: jnp.ndarray, pw: PackedWeight, block_k: int = 512
) -> jnp.ndarray:
    """K-blocked scan: unpack+decode one K-tile at a time, accumulate in f32.

    Bounds the dequantized working set to [bK, N] regardless of K, so the
    HBM traffic XLA sees is dominated by the *packed* planes — this is the
    paper's memory-saving made visible to the XLA scheduler without Pallas.
    """
    lay = pw.layout
    K, N = pw.K, pw.N
    Kp = lay.padded_k(K)
    # choose a block that's a multiple of the packing block
    bK = max(lay.k_block, (block_k // lay.k_block) * lay.k_block)
    nb = -(-Kp // bK)
    Kpp = nb * bK

    xb = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, Kpp - K)))
    xb = xb.reshape(x.shape[0], nb, bK).transpose(1, 0, 2)  # [nb, B, bK]

    hi = jnp.pad(pw.hi, ((0, Kpp // lay.per_word - pw.hi.shape[0]), (0, 0)))
    hi = hi.reshape(nb, bK // lay.per_word, N)
    k = lay.scheme.k
    if lay.container == "planes" and k > 1:
        lr = Kpp // (32 * k)
        lsb = jnp.pad(pw.lsb, ((0, lr - pw.lsb.shape[0]), (0, 0)))
        lsb = lsb.reshape(nb, bK // (32 * k), N)
    else:
        lsb = jnp.zeros((nb, 1, N), jnp.int32)

    def body(acc, blk):
        xk, hik, lsbk = blk
        sub = PackedWeight(hik, lsbk if (lay.container == "planes" and k > 1)
                           else jnp.zeros((0, N), jnp.int32),
                           jnp.ones((N,), jnp.float32), lay, bK, N)
        w = _decode_codes(sub, unpack(sub))
        return acc + jnp.dot(xk, w, preferred_element_type=jnp.float32), None

    acc0 = jnp.zeros((x.shape[0], N), jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (xb, hi, lsb))
    return acc * pw.scale[None, :]
