"""Block-shape selection + VMEM budgeting for the Pallas kernels.

Two planners live here:

  * `plan_tiles` — the AMS matmul (bB, bK, bN) tile. The dry-run has no
    wall clock, so tile choice is *structural*: pick the largest
    MXU-aligned (bK, bN) whose working set fits the VMEM budget with
    double-buffered input streams, preferring K-depth (amortizes the f32
    accumulator) over N-width. This is the reasoning the §Perf Pallas
    hints prescribe — from the lowered resource model, not a trace.
  * `plan_attention_tiles` — the KV block size of the fused attention
    template (`kernels.attention_template`), fronted by a PERSISTENT
    per-(shape, family, scheme) `AutotuneCache`. The default plan is
    deterministic (largest divisor of the cache length whose working set
    fits the budget — CI stays reproducible); pass a ``measure`` callable
    (plan -> seconds) to pick by wall clock instead, and the winner is
    persisted so later sessions reuse it. Set the
    ``REPRO_ATTN_AUTOTUNE_CACHE`` env var to a JSON path to persist
    across processes.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Callable, Dict, Optional

from repro.core.formats import get_scheme
from repro.core.kv_quant import packed_head_dim
from repro.core.packing import PackLayout

VMEM_BYTES = 16 * 2 ** 20  # v5e per-core VMEM


@dataclasses.dataclass(frozen=True)
class TilePlan:
    bb: int
    bk: int
    bn: int
    vmem_bytes: int
    pipeline_buffers: int = 2  # double buffering


def vmem_usage(lay: PackLayout, bb: int, bk: int, bn: int,
               buffers: int = 2) -> int:
    """Bytes of VMEM a (bb, bk, bn) tile claims in ams_matmul."""
    k = lay.scheme.k
    hi = 4 * (bk // lay.per_word) * bn
    lsb = 4 * (bk // (32 * k)) * bn if (lay.container == "planes" and k > 1) else 0
    x = 4 * bb * bk
    scale = 4 * bn
    streams = buffers * (hi + lsb + x + scale)        # double-buffered DMAs
    decoded = 4 * bk * bn                              # f32 restore tile
    acc = 4 * bb * bn                                  # f32 accumulator
    out = 4 * bb * bn
    return streams + decoded + acc + out


def plan_tiles(lay: PackLayout, B: int, K: int, N: int,
               budget: int = VMEM_BYTES) -> TilePlan:
    """Largest aligned tile under budget; K-major growth."""
    bb = min(max(8, 1 << (B - 1).bit_length()), 128)
    base_k = math.lcm(lay.k_block, 128)
    best = None
    for bn in (512, 256, 128):
        for mult in (8, 6, 4, 3, 2, 1):
            bk = base_k * mult
            if bk > max(base_k, K * 2):
                continue
            use = vmem_usage(lay, bb, bk, bn)
            if use <= budget:
                cand = TilePlan(bb, bk, bn, use)
                if best is None or (cand.bk * cand.bn) > (best.bk * best.bn):
                    best = cand
        if best is not None:
            break
    if best is None:  # fall back to the minimum legal tile
        best = TilePlan(8, base_k, 128, vmem_usage(lay, 8, base_k, 128))
    return best


# ---------------------------------------------------------------------------
# Fused-attention KV-block planning (persistent autotune cache)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AttnTilePlan:
    """One KV-block choice for the fused attention template."""

    block_kv: int        # keys per grid step (page_size when paged)
    rows: int            # folded query rows (chunk * group) per cell
    vmem_bytes: int      # structural working-set estimate
    source: str = "default"   # default | measured | fallback | cache


def attn_vmem_usage(rows: int, block_kv: int, hd: int,
                    hd_v: Optional[int] = None, scheme: Optional[str] = None,
                    buffers: int = 2) -> int:
    """Bytes of VMEM one (rows, block_kv) attention cell claims: the
    double-buffered K/V streams (packed planes for an AMS scheme, else f32
    upper bound), the in-VREG restore tiles, q, the f32 accumulator and the
    (rows, 128) m/l scratch columns."""
    hd_v = hd if hd_v is None else hd_v
    if scheme is not None:
        fmt = get_scheme(scheme)
        hd_p = packed_head_dim(hd, fmt)
        gw = -(-(hd_p // fmt.k) // 32)
        plane = block_kv * (hd_p // 2) + 4 * block_kv * gw + 4 * block_kv
        streams = buffers * 2 * plane                  # K and V plane DMAs
        decoded = 4 * block_kv * (hd + hd_v)           # f32 restore tiles
    else:
        streams = buffers * 4 * block_kv * (hd + hd_v)
        decoded = 0
    q = 4 * rows * hd
    acc = 4 * rows * hd_v
    ml = 2 * 4 * rows * 128
    out = 4 * rows * hd_v
    return streams + decoded + q + acc + ml + out


def attn_plan_key(*, kind: str, family: str, scheme: Optional[str],
                  rows: int, hd: int, hd_v: int, s_max: int,
                  page: int = 0, kv_heads: int = 0,
                  budget: int = VMEM_BYTES) -> str:
    """Canonical per-(shape, family, scheme) cache key.

    ``kv_heads`` is the LOCAL (per-shard) kv-head count of the launching
    grid and ``budget`` the VMEM budget the plan was selected under: a
    tensor-parallel engine hands each device a head SLICE of the cache, so
    a plan tuned at tp=1 (full heads, default budget) must never be
    silently served for a tp=4 slice — different grid height, different
    occupancy. 0 = unspecified (pre-sharding callers), kept distinct from
    any real count."""
    return (f"{kind}/{family}/{scheme or 'bf16'}/rows{rows}/hd{hd}"
            f"v{hd_v}/s{s_max}/p{page}/kv{kv_heads}/vb{budget}")


class AutotuneCache:
    """Persistent plan store: a dict keyed by `attn_plan_key`, mirrored to
    a JSON file when a path is given (load on construction, rewrite on
    every put). Plans round-trip exactly — `source` is stored so a
    measured plan stays marked measured after reload."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._plans: Dict[str, AttnTilePlan] = {}
        if path is not None and os.path.exists(path):
            self.load(path)

    def __len__(self) -> int:
        return len(self._plans)

    def get(self, key: str) -> Optional[AttnTilePlan]:
        return self._plans.get(key)

    def put(self, key: str, plan: AttnTilePlan) -> None:
        self._plans[key] = plan
        if self.path is not None:
            self.save(self.path)

    def load(self, path: str) -> None:
        with open(path) as f:
            raw = json.load(f)
        for k, d in raw.items():
            self._plans[k] = AttnTilePlan(**d)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({k: dataclasses.asdict(p)
                       for k, p in sorted(self._plans.items())}, f, indent=1)


_ATTN_CACHE: Optional[AutotuneCache] = None


def get_autotune_cache() -> AutotuneCache:
    """Process-wide cache; persists to $REPRO_ATTN_AUTOTUNE_CACHE if set."""
    global _ATTN_CACHE
    if _ATTN_CACHE is None:
        _ATTN_CACHE = AutotuneCache(os.environ.get("REPRO_ATTN_AUTOTUNE_CACHE"))
    return _ATTN_CACHE


def _divisors_desc(n: int):
    out = {n}
    for i in range(1, int(math.isqrt(n)) + 1):
        if n % i == 0:
            out.add(i)
            out.add(n // i)
    return sorted(out, reverse=True)


def plan_attention_tiles(*, kind: str, family: str, scheme: Optional[str],
                         rows: int, hd: int, hd_v: Optional[int] = None,
                         s_max: int, page: int = 0, kv_heads: int = 0,
                         budget: int = VMEM_BYTES,
                         cache: Optional[AutotuneCache] = None,
                         measure: Optional[Callable[[AttnTilePlan], float]]
                         = None) -> AttnTilePlan:
    """KV-block plan for one fused-attention shape.

    ``kind`` is "paged" (block fixed at ``page``) or "contiguous" (block
    chosen from the divisors of ``s_max`` — a block never reads past the
    cache). Deterministic default: the LARGEST candidate whose
    `attn_vmem_usage` fits ``budget``; none fitting falls back to the
    smallest divisor (marked ``source="fallback"``). A ``measure``
    callable re-ranks the fitting candidates by measured seconds
    (ties break to the larger block) and is never consulted on a cache
    hit already measured. Results persist via ``cache`` (defaults to the
    process-wide `get_autotune_cache`). ``kv_heads`` is the launching
    grid's LOCAL kv-head count (per-shard under tensor parallelism) and
    joins ``budget`` in the cache key — see `attn_plan_key`."""
    hd_v = hd if hd_v is None else hd_v
    cache = cache if cache is not None else get_autotune_cache()
    key = attn_plan_key(kind=kind, family=family, scheme=scheme, rows=rows,
                        hd=hd, hd_v=hd_v, s_max=s_max, page=page,
                        kv_heads=kv_heads, budget=budget)
    hit = cache.get(key)
    if hit is not None and (measure is None or hit.source == "measured"):
        return hit
    if kind == "paged":
        plan = AttnTilePlan(page, rows,
                            attn_vmem_usage(rows, page, hd, hd_v, scheme))
        cache.put(key, plan)
        return plan
    cands = [AttnTilePlan(bk, rows, attn_vmem_usage(rows, bk, hd, hd_v,
                                                    scheme))
             for bk in _divisors_desc(s_max)]
    fitting = [p for p in cands if p.vmem_bytes <= budget]
    if not fitting:
        plan = dataclasses.replace(cands[-1], source="fallback")
    elif measure is not None:
        timed = [(measure(p), -p.block_kv, p) for p in fitting]
        plan = dataclasses.replace(min(timed)[2], source="measured")
    else:
        plan = fitting[0]                      # largest fitting block
    cache.put(key, plan)
    return plan
