"""Block-shape selection + VMEM budgeting for the AMS matmul kernel.

The dry-run has no wall clock, so tile choice is *structural*: pick the
largest MXU-aligned (bK, bN) whose working set fits the VMEM budget with
double-buffered input streams, preferring K-depth (amortizes the f32
accumulator) over N-width. This is the reasoning the §Perf Pallas hints
prescribe — from the lowered resource model, not a trace.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.packing import PackLayout

VMEM_BYTES = 16 * 2 ** 20  # v5e per-core VMEM


@dataclasses.dataclass(frozen=True)
class TilePlan:
    bb: int
    bk: int
    bn: int
    vmem_bytes: int
    pipeline_buffers: int = 2  # double buffering


def vmem_usage(lay: PackLayout, bb: int, bk: int, bn: int,
               buffers: int = 2) -> int:
    """Bytes of VMEM a (bb, bk, bn) tile claims in ams_matmul."""
    k = lay.scheme.k
    hi = 4 * (bk // lay.per_word) * bn
    lsb = 4 * (bk // (32 * k)) * bn if (lay.container == "planes" and k > 1) else 0
    x = 4 * bb * bk
    scale = 4 * bn
    streams = buffers * (hi + lsb + x + scale)        # double-buffered DMAs
    decoded = 4 * bk * bn                              # f32 restore tile
    acc = 4 * bb * bn                                  # f32 accumulator
    out = 4 * bb * bn
    return streams + decoded + acc + out


def plan_tiles(lay: PackLayout, B: int, K: int, N: int,
               budget: int = VMEM_BYTES) -> TilePlan:
    """Largest aligned tile under budget; K-major growth."""
    bb = min(max(8, 1 << (B - 1).bit_length()), 128)
    base_k = math.lcm(lay.k_block, 128)
    best = None
    for bn in (512, 256, 128):
        for mult in (8, 6, 4, 3, 2, 1):
            bk = base_k * mult
            if bk > max(base_k, K * 2):
                continue
            use = vmem_usage(lay, bb, bk, bn)
            if use <= budget:
                cand = TilePlan(bb, bk, bn, use)
                if best is None or (cand.bk * cand.bn) > (best.bk * best.bn):
                    best = cand
        if best is not None:
            break
    if best is None:  # fall back to the minimum legal tile
        best = TilePlan(8, base_k, 128, vmem_usage(lay, 8, base_k, 128))
    return best
