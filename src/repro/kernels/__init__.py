# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# This paper has two:
#   * ams_matmul — packed-plane AMS matmul (weights stay quantized in HBM)
#   * attention_template — ONE fused online-softmax decode template that
#     every serving attention path lowers through: paged/contiguous caches,
#     bf16 and packed-AMS K/V (restored in VREGs), GQA/MLA families, ragged
#     multi-query rows. Tile planning + the per-(shape, family, scheme)
#     autotune cache live in kernels.tuning.
from repro.kernels.attention_template import (  # noqa: F401
    attend_contiguous,
    flash_decode,
    flash_decode_chunk,
    fused_contiguous_attention,
    fused_paged_attention,
)
from repro.kernels.tuning import (  # noqa: F401
    AttnTilePlan,
    AutotuneCache,
    plan_attention_tiles,
)
