"""One templated online-softmax attention kernel for every decode path.

Every decode-time attention in this repo — the paged AMS/bf16 kernel in
`cache/paged_attention.py`, the contiguous GQA cores and the absorbed-MLA
cores in `models/attention.py` — is the same loop: scale q, walk KV in
blocks, accumulate a running (m, l, acc) online softmax with the additive
-2e30 mask, normalize once at the end. This module is the single home of
that loop, parameterized on three hooks (the AttentionEngine
score-mod/online-rowscale design, SNIPPETS.md Snippet 2):

  (a) **K/V load hook** — how one KV block reaches VREGs:
      * bf16/f32 pages or contiguous cache rows, cast to f32;
      * packed-e2m2 AMS planes (hi nibbles / shared-LSB words / scales)
        restored to exact lattice values in-kernel (`restore_page`) —
        dequantized pages are NEVER materialized in HBM, which is where
        the paper's 2.8-3.2x decode win lives;
      * a single compressed stream whose VALUES are its first ``hd_v``
        columns (absorbed MLA: v = k[:, :r_kv], nothing extra loaded).
  (b) **score-mod hook** — the family mapping: GQA's head-group fold is
      done host-side (q reshaped to chunk-major rows per kv head, so the
      kernel body is family-blind), MLA supplies its effective-rank scale
      and the value-slice width.
  (c) **ragged rows** — a [B, c] chunk folds its c queries into the row
      dimension of one grid cell; per-query lengths ride SCALAR PREFETCH
      (`pltpu.PrefetchScalarGridSpec`) next to the (paged-only) block
      table, so BlockSpec index_maps see them before the body runs.

Two lowering tiers share the math:

  * `flash_decode` / `flash_decode_chunk` — the plain-XLA reference
    bodies (moved verbatim from `models.attention`; still re-exported
    there). These are the serving default (`impl="ref"`) and the oracle
    every fused path is pinned against; they also carry the
    sequence-sharded collectives (pmax/psum over ``axis_name``) that the
    fused kernel does not support.
  * `fused_paged_attention` / `fused_contiguous_attention` — the Pallas
    template (`impl="pallas"`/`"pallas_interpret"`), one grid
    (B, kv_heads, kv_blocks) with the KV dimension innermost
    ("arbitrary") and (m, l, acc) in VMEM scratch across it.

`attend_contiguous` is the dispatch the models cores call: it routes to
the fused template when the impl asks for it AND the case is fusable
(group-major layout, no mesh collectives, no ring/sliding window), and
otherwise falls back to the bit-identical XLA path. Contiguous block
sizes come from `kernels.tuning.plan_attention_tiles` — a persistent
per-(shape, family, scheme) autotune cache with a deterministic
VMEM-budgeted default; set ``REPRO_ATTN_MEASURE=1`` to pick the block by
wall-clock instead (never in CI).
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import get_scheme
from repro.core.kv_quant import codes_from_planes, packed_head_dim
# _CompilerParams: the CompilerParams/TPUCompilerParams rename shim
from repro.kernels.ams_matmul import _CompilerParams, decode_codes_to_f32
from repro.kernels.tuning import plan_attention_tiles

NEG_BIG = -2e30   # additive mask; exp(NEG_BIG - NEG_CLAMP) == 0 exactly
NEG_CLAMP = -1e30


# ---------------------------------------------------------------------------
# XLA reference bodies (the `impl="ref"` tier and the fused path's oracle)
# ---------------------------------------------------------------------------
def _cache_positions(S_loc: int, pos, shard, ring_window: int):
    """Global key position held by each local cache slot.

    Full cache: slot j on shard s holds position s*S_loc + j. Ring (sliding
    window) cache of width W: global slot g holds the largest p <= pos with
    p % W == g (older entries were overwritten).
    """
    g = shard * S_loc + jnp.arange(S_loc)
    if ring_window:
        return pos - ((pos - g) % ring_window)
    return g


def flash_decode(
    q: jnp.ndarray,            # [B, H, hd]
    k_cache: jnp.ndarray,      # [B, S_loc, kv, hd]
    v_cache: jnp.ndarray,      # [B, S_loc, kv, hd_v]
    pos: jnp.ndarray,          # int32 current length (num valid keys):
                               #   scalar (shared) or [B] (per-slot lengths)
    *,
    kv_map: np.ndarray,
    axis_name: Optional[str] = None,   # mesh axis the S dim is sharded over
    window: int = 0,
    ring: bool = False,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    B, H, hd = q.shape
    S_loc = k_cache.shape[1]
    hd_v = v_cache.shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    shard = jax.lax.axis_index(axis_name) if axis_name else 0
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    pos_b = pos[:, None] if per_slot else pos  # broadcasts against [S_loc]
    k_pos = _cache_positions(S_loc, pos_b - 1, shard, window if ring else 0)

    kv_n = k_cache.shape[2]
    grouped = (H % kv_n == 0) and np.array_equal(
        kv_map, np.arange(H) // (H // kv_n))
    qf = q * np.float32(scale).astype(q.dtype)
    if grouped:
        g = H // kv_n
        qg = qf.reshape(B, kv_n, g, hd)
        s = jnp.einsum("bngd,bknd->bngk", qg, k_cache,
                       preferred_element_type=jnp.float32).reshape(B, H, S_loc)
    else:
        kvm = jnp.asarray(kv_map)
        ke = k_cache[:, :, kvm, :]
        s = jnp.einsum("bhd,bkhd->bhk", qf, ke,
                       preferred_element_type=jnp.float32)
    valid = (k_pos >= 0) & (k_pos < pos_b)  # ring slots may map to pre-history
    if window > 0:
        valid = valid & (pos_b - 1 - k_pos < window)
    # [B, 1, S_loc] when per-slot, [1, 1, S_loc] when shared
    vmask = valid[:, None, :] if per_slot else valid[None, None, :]
    s = jnp.where(vmask, s, -jnp.inf)

    m = s.max(axis=-1)                                   # [B, H]
    if axis_name:
        m = jax.lax.pmax(m, axis_name)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(vmask, p, 0.0)
    l = p.sum(axis=-1)                                   # [B, H]
    if grouped:
        g = H // kv_n
        pg = p.reshape(B, kv_n, g, S_loc)
        o = jnp.einsum("bngk,bknd->bngd", pg.astype(v_cache.dtype), v_cache,
                       preferred_element_type=jnp.float32).reshape(B, H, hd_v)
    else:
        ve = v_cache[:, :, kvm, :]
        o = jnp.einsum("bhk,bkhd->bhd", p.astype(ve.dtype), ve,
                       preferred_element_type=jnp.float32)
    if axis_name:
        l = jax.lax.psum(l, axis_name)
        o = jax.lax.psum(o, axis_name)
    return (o / jnp.maximum(l, 1e-20)[..., None]).astype(q.dtype)


def flash_decode_chunk(
    q: jnp.ndarray,            # [B, c, H, hd] query block (c <= chunk size)
    k_cache: jnp.ndarray,      # [B, S_loc, kv, hd]
    v_cache: jnp.ndarray,      # [B, S_loc, kv, hd_v]
    lengths: jnp.ndarray,      # [B, c] int32 valid keys PER QUERY (0 = masked
                               #   row -> exact-zero output)
    *,
    kv_map: np.ndarray,
    axis_name: Optional[str] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Chunked flash-decode: a [B, c] ragged query block attends the cache.

    Intra-chunk causality is carried entirely by ``lengths``: the caller
    inserts the chunk's keys FIRST, then sets query j's length to
    ``start + j + 1`` — so each query sees the prefix plus itself and the
    chunk entries before it, never the ones after. Rows past a slot's valid
    count get length 0 and flush to exact zeros (the engine discards them).
    Same additive-mask online-softmax math as `flash_decode`; no ring /
    sliding-window support (chunked mode is gated to plain-GQA / MLA
    families).
    """
    B, c, H, hd = q.shape
    S_loc = k_cache.shape[1]
    hd_v = v_cache.shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    shard = jax.lax.axis_index(axis_name) if axis_name else 0
    lengths = jnp.asarray(lengths, jnp.int32)
    k_pos = shard * S_loc + jnp.arange(S_loc)        # [S_loc] global positions

    kv_n = k_cache.shape[2]
    grouped = (H % kv_n == 0) and np.array_equal(
        kv_map, np.arange(H) // (H // kv_n))
    qf = q * np.float32(scale).astype(q.dtype)
    if grouped:
        g = H // kv_n
        qg = qf.reshape(B, c, kv_n, g, hd)
        s = jnp.einsum("bcngd,bknd->bcngk", qg, k_cache,
                       preferred_element_type=jnp.float32)
        s = s.reshape(B, c, H, S_loc)
    else:
        kvm = jnp.asarray(kv_map)
        ke = k_cache[:, :, kvm, :]
        s = jnp.einsum("bchd,bkhd->bchk", qf, ke,
                       preferred_element_type=jnp.float32)
    valid = k_pos[None, None, :] < lengths[:, :, None]   # [B, c, S_loc]
    vmask = valid[:, :, None, :]                          # [B, c, 1, S_loc]
    s = jnp.where(vmask, s, -jnp.inf)

    m = s.max(axis=-1)                                    # [B, c, H]
    if axis_name:
        m = jax.lax.pmax(m, axis_name)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(vmask, p, 0.0)
    l = p.sum(axis=-1)                                    # [B, c, H]
    if grouped:
        g = H // kv_n
        pg = p.reshape(B, c, kv_n, g, S_loc)
        o = jnp.einsum("bcngk,bknd->bcngd", pg.astype(v_cache.dtype), v_cache,
                       preferred_element_type=jnp.float32)
        o = o.reshape(B, c, H, hd_v)
    else:
        ve = v_cache[:, :, kvm, :]
        o = jnp.einsum("bchk,bkhd->bchd", p.astype(ve.dtype), ve,
                       preferred_element_type=jnp.float32)
    if axis_name:
        l = jax.lax.psum(l, axis_name)
        o = jax.lax.psum(o, axis_name)
    return (o / jnp.maximum(l, 1e-20)[..., None]).astype(q.dtype)


# ---------------------------------------------------------------------------
# In-kernel pieces (shared by every fused layout)
# ---------------------------------------------------------------------------
def restore_page(hi, lsb, scale, fmt, k: int, hd: int) -> jnp.ndarray:
    """AMS load hook: packed planes of one (block, kv-head) cell ->
    [block, hd] f32 lattice values, restored in VREGs with the same
    SHIFT/AND/OR sequence as the weight kernel. hi: [block, hd_p//2] int8,
    lsb: [block, gw] int32, scale: [block, 1] f32."""
    codes = codes_from_planes(hi, lsb, k)
    vals = decode_codes_to_f32(codes, fmt) * scale
    return vals[:, :hd]


def row_lengths(len_ref, b, c: int, g: int):
    """Per-ROW valid-key counts [c*g, 1] for a chunked query block: the
    flattened lengths ride scalar prefetch as [B*c]; row r of the (c, g)-
    folded query block belongs to query r // g. c and g are static, so the
    gather is c scalar SMEM reads."""
    lv = jnp.stack([len_ref[b * c + j] for j in range(c)])      # [c]
    return jnp.repeat(lv, g, total_repeat_length=c * g)[:, None]


def online_softmax_step(qf, k_blk, v_blk, length, i, nb, o_ref,
                        acc_ref, m_ref, l_ref, *, pv_dtype=jnp.float32):
    """One KV block of flash-decode accumulation — THE loop body every
    fused layout shares. qf [rows, hd] f32 (pre-scaled; rows = chunk*group
    for ragged blocks), k_blk [block, hd] / v_blk [block, hd_v] f32,
    ``length`` a scalar or per-row [rows, 1] valid-key count. ``pv_dtype``
    mirrors flash_decode's ``p.astype(v.dtype)`` before the PV product
    (bf16 caches cast, AMS lattice values stay f32) so the oracle and the
    kernel round alike."""
    block = k_blk.shape[0]

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_CLAMP)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    s = jax.lax.dot_general(qf, k_blk, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [rows, block]
    k_pos = i * block + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    s = s + jnp.where(k_pos < length, 0.0, NEG_BIG)

    m_prev = m_ref[:, :1]                                  # [rows, 1]
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(jnp.maximum(m_prev, s.max(axis=-1, keepdims=True)),
                        NEG_CLAMP)
    p = jnp.exp(s - m_new)                                 # masked -> exact 0
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(pv_dtype), v_blk.astype(pv_dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(i == nb - 1)
    def _done():
        l = l_ref[:, :1]
        out = acc_ref[...] / jnp.maximum(l, 1e-20)
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


# --- K/V load hooks -------------------------------------------------------
def _load_pair(kv_refs):
    """Separate K and V tensors (bf16/f32 pages or contiguous rows)."""
    k_ref, v_ref = kv_refs
    return (k_ref[0, :, 0, :].astype(jnp.float32),
            v_ref[0, :, 0, :].astype(jnp.float32))


def _make_load_stream(hd_v: int):
    """One compressed stream; values are its first hd_v columns (absorbed
    MLA) — V costs zero extra HBM reads."""
    def load(kv_refs):
        (k_ref,) = kv_refs
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        return k, k[:, :hd_v]
    return load


def _make_load_ams(fmt, k_share: int, hd: int, hd_v: Optional[int]):
    """AMS packed planes; with ``hd_v`` set, a single quantized stream whose
    values are the first hd_v restored columns."""
    def load(kv_refs):
        if hd_v is None:
            khi, klsb, ksc, vhi, vlsb, vsc = kv_refs
            k = restore_page(khi[0, :, 0, :], klsb[0, :, 0, :],
                             ksc[0, :, 0, :], fmt, k_share, hd)
            v = restore_page(vhi[0, :, 0, :], vlsb[0, :, 0, :],
                             vsc[0, :, 0, :], fmt, k_share, hd)
            return k, v
        khi, klsb, ksc = kv_refs
        k = restore_page(khi[0, :, 0, :], klsb[0, :, 0, :],
                         ksc[0, :, 0, :], fmt, k_share, hd)
        return k, k[:, :hd_v]
    return load


def _make_body(*, load_kv, nb: int, chunk: int, g: int, pv_dtype,
               num_scalars: int):
    """Assemble one kernel body from a load hook. Ref order is fixed by the
    grid spec: [scalar prefetch...(lengths last), q, *kv operands, out,
    acc, m, l]."""
    def body(*refs):
        len_ref = refs[num_scalars - 1]
        q_ref = refs[num_scalars]
        kv_refs = refs[num_scalars + 1:-4]
        o_ref, acc_ref, m_ref, l_ref = refs[-4:]
        b, i = pl.program_id(0), pl.program_id(2)
        qf = q_ref[0, 0].astype(jnp.float32)
        k_blk, v_blk = load_kv(kv_refs)
        online_softmax_step(qf, k_blk, v_blk,
                            row_lengths(len_ref, b, chunk, g), i, nb,
                            o_ref, acc_ref, m_ref, l_ref, pv_dtype=pv_dtype)
    return body


# --- host-side fold / launch ----------------------------------------------
def _fold_q(q, lengths, kv_n: int, scale):
    """Scale q in q.dtype (the exact rounding flash_decode applies), fold
    the GQA groups chunk-major into the row dim ([B, kv, c*g, hd]), and
    flatten lengths to the [B*c] scalar-prefetch stream."""
    chunked = q.ndim == 4
    if not chunked:
        q = q[:, None]
        lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32),
                                   (q.shape[0],))[:, None]
    B, c, H, hd = q.shape
    if H % kv_n != 0:
        raise ValueError(f"H={H} not grouped over kv={kv_n}")
    g = H // kv_n
    rows = c * g
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    qf = (q * np.float32(scale).astype(q.dtype)).astype(jnp.float32)
    # [B, c, kv, g, hd] -> [B, kv, c, g, hd]: chunk-major rows per kv head
    qf = qf.reshape(B, c, kv_n, g, hd).transpose(0, 2, 1, 3, 4)
    qf = qf.reshape(B, kv_n, rows, hd)
    lens = jnp.asarray(lengths, jnp.int32).reshape(-1)        # [B*c]
    return qf, lens, chunked, (B, c, H, hd, g, rows)


def _unfold_o(o, dims, hd_v: int, chunked: bool, dtype):
    B, c, H, hd, g, rows = dims
    kv_n = H // g
    o = o.reshape(B, kv_n, c, g, hd_v).transpose(0, 2, 1, 3, 4)
    o = o.reshape(B, c, H, hd_v).astype(dtype)
    return o if chunked else o[:, 0]


def _launch(body, grid, num_scalars, in_specs, out_spec, scalar_args,
            operands, *, rows, hd_v, interpret):
    scratch = [pltpu.VMEM((rows, hd_v), jnp.float32),   # acc
               pltpu.VMEM((rows, 128), jnp.float32),    # m (col 0 live)
               pltpu.VMEM((rows, 128), jnp.float32)]    # l (col 0 live)
    B, kv_n, _ = grid
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_scalars, grid=grid,
        in_specs=in_specs, out_specs=out_spec, scratch_shapes=scratch)
    return pl.pallas_call(
        body, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, kv_n, rows, hd_v), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*scalar_args, *operands)


# ---------------------------------------------------------------------------
# Fused entry: paged pools (block table on scalar prefetch)
# ---------------------------------------------------------------------------
def fused_paged_attention(
    q: jnp.ndarray,              # [B, H, hd] or [B, c, H, hd] UNSCALED
    pool,                        # layer pool (cache.pool layout)
    lengths: jnp.ndarray,        # [B] int32 valid keys (<=0: idle slot);
                                 #   [B, c] per-query for chunked q
    block_table: jnp.ndarray,    # [B, max_pages_per_seq] int32
    *,
    page_size: int,
    kv_scheme: Optional[str] = None,   # AMS scheme name; None = bf16 pages
    value_slice: Optional[int] = None,  # MLA: v = k[:, :value_slice]
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Paged flash-decode through the template. Requires the group-major
    GQA head layout (the only layout the model zoo emits — see
    `kv_index_map`); returns q's shape in q.dtype. One grid step attends
    one (slot, kv-head, page) cell; the block table and the flattened
    per-query lengths ride the same scalar-prefetch stream, so each page's
    BlockSpec index_map dereferences ``block_table[b, i]`` BEFORE the body
    runs and the pipeline DMAs exactly the pages the slot owns."""
    kv_n = jax.tree.leaves(pool["k"])[0].shape[2]
    qf, lens, chunked, dims = _fold_q(q, lengths, kv_n, scale)
    B, c, H, hd, g, rows = dims
    hd_v = hd if value_slice is None else value_slice
    page = page_size
    nb = block_table.shape[1]
    bt_flat = block_table.reshape(-1).astype(jnp.int32)

    # index maps: scalar-prefetch refs arrive after the grid indices
    q_spec = pl.BlockSpec((1, 1, rows, hd), lambda b, h, i, bt, ln: (b, h, 0, 0))
    out_spec = pl.BlockSpec((1, 1, rows, hd_v),
                            lambda b, h, i, bt, ln: (b, h, 0, 0))

    def page_spec(block_tail):
        return pl.BlockSpec(
            (1, page) + block_tail,
            lambda b, h, i, bt, ln: (bt[b * nb + i], 0, h) + (0,) * (len(block_tail) - 1))

    if kv_scheme is not None:
        scheme = get_scheme(kv_scheme)
        hd_p = packed_head_dim(hd, scheme)
        gw = pool["k"]["lsb"].shape[-1]
        load = _make_load_ams(scheme.base, scheme.k, hd, value_slice)
        plane_specs = [page_spec((1, hd_p // 2)), page_spec((1, gw)),
                       page_spec((1, 1))]
        operands = [qf, pool["k"]["hi"], pool["k"]["lsb"], pool["k"]["scale"]]
        in_specs = [q_spec] + plane_specs
        if value_slice is None:
            operands += [pool["v"]["hi"], pool["v"]["lsb"], pool["v"]["scale"]]
            in_specs += plane_specs
        pv_dtype = jnp.float32
    else:
        if value_slice is None:
            load = _load_pair
            in_specs = [q_spec, page_spec((1, hd)), page_spec((1, hd))]
            operands = [qf, pool["k"], pool["v"]]
        else:
            load = _make_load_stream(value_slice)
            in_specs = [q_spec, page_spec((1, hd))]
            operands = [qf, pool["k"]]
        pv_dtype = jax.tree.leaves(pool["k"])[0].dtype

    body = _make_body(load_kv=load, nb=nb, chunk=c, g=g, pv_dtype=pv_dtype,
                      num_scalars=2)
    o = _launch(body, (B, kv_n, nb), 2, in_specs, out_spec,
                (bt_flat, lens), operands, rows=rows, hd_v=hd_v,
                interpret=interpret)
    return _unfold_o(o, dims, hd_v, chunked, q.dtype)


# ---------------------------------------------------------------------------
# Fused entry: contiguous caches (autotuned KV block)
# ---------------------------------------------------------------------------
def fused_contiguous_attention(
    q: jnp.ndarray,              # [B, H, hd] or [B, c, H, hd] UNSCALED
    k_cache: jnp.ndarray,        # [B, S_loc, kv, hd]
    lengths: jnp.ndarray,        # [B] or [B, c] int32 valid keys
    *,
    v_cache: Optional[jnp.ndarray] = None,   # [B, S_loc, kv, hd]; None with
    value_slice: Optional[int] = None,       #   value_slice (MLA stream)
    block_kv: Optional[int] = None,          # override the autotune plan
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Contiguous-cache flash-decode through the same template: grid
    (B, kv_heads, S_loc/block_kv), cache rows DMA'd block-by-block, lengths
    on scalar prefetch. ``block_kv`` comes from the per-(shape, family)
    autotune cache (`kernels.tuning.plan_attention_tiles`) unless
    overridden; candidates are divisors of S_loc so no block ever reads
    past the cache."""
    kv_n = k_cache.shape[2]
    S_loc = k_cache.shape[1]
    qf, lens, chunked, dims = _fold_q(q, lengths, kv_n, scale)
    B, c, H, hd, g, rows = dims
    hd_v = hd if value_slice is None else value_slice
    if value_slice is None and v_cache is None:
        raise ValueError("need v_cache or value_slice")

    if value_slice is None:
        load = _load_pair
        n_kv = 2
    else:
        load = _make_load_stream(value_slice)
        n_kv = 1
    pv_dtype = (v_cache if v_cache is not None else k_cache).dtype

    def run(bk: int):
        nb = S_loc // bk
        q_spec = pl.BlockSpec((1, 1, rows, hd), lambda b, h, i, ln: (b, h, 0, 0))
        out_spec = pl.BlockSpec((1, 1, rows, hd_v),
                                lambda b, h, i, ln: (b, h, 0, 0))
        kv_spec = pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, ln: (b, i, h, 0))
        in_specs = [q_spec] + [kv_spec] * n_kv
        operands = ([qf, k_cache, v_cache] if value_slice is None
                    else [qf, k_cache])
        body = _make_body(load_kv=load, nb=nb, chunk=c, g=g,
                          pv_dtype=pv_dtype, num_scalars=1)
        o = _launch(body, (B, kv_n, nb), 1, in_specs, out_spec, (lens,),
                    operands, rows=rows, hd_v=hd_v, interpret=interpret)
        return o

    if block_kv is None:
        family = "mla" if value_slice is not None else "gqa"
        measure = None
        if os.environ.get("REPRO_ATTN_MEASURE") == "1":
            import time

            def measure(plan):
                jax.block_until_ready(run(plan.block_kv))      # compile+warm
                t0 = time.perf_counter()
                jax.block_until_ready(run(plan.block_kv))
                return time.perf_counter() - t0
        # kv_n is the LOCAL head count of the operand (a per-shard slice
        # under tensor parallelism) — it keys the plan so a tp=1 tuning is
        # never silently reused for a different grid height
        plan = plan_attention_tiles(
            kind="contiguous", family=family, scheme=None, rows=rows,
            hd=hd, hd_v=hd_v, s_max=S_loc, kv_heads=kv_n, measure=measure)
        block_kv = plan.block_kv
    if S_loc % block_kv != 0:
        raise ValueError(f"block_kv={block_kv} must divide S_loc={S_loc}")
    o = run(block_kv)
    return _unfold_o(o, dims, hd_v, chunked, q.dtype)


# ---------------------------------------------------------------------------
# Dispatch: the single entry the models cores call
# ---------------------------------------------------------------------------
def attend_contiguous(
    q: jnp.ndarray,              # [B, H, hd] (one-token) or [B, c, H, hd]
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,        # ref-path values (MLA: the [..., :r_kv] view)
    lengths: jnp.ndarray,        # one-token: pos+1 (scalar or [B]);
                                 #   chunked: [B, c] per-query lengths
    *,
    kv_map: np.ndarray,
    scale: Optional[float] = None,
    impl: str = "ref",
    axis_name: Optional[str] = None,
    window: int = 0,
    ring: bool = False,
    value_slice: Optional[int] = None,   # MLA: fuse v = k_cache[..., :r_kv]
) -> jnp.ndarray:
    """Decode attention over a contiguous cache, routed by ``impl``.

    ``impl="ref"`` (the serving default) IS `flash_decode` /
    `flash_decode_chunk` — bit-identical to the pre-template cores.
    ``impl="pallas"``/``"pallas_interpret"`` lowers through the fused
    template when the case is fusable; sequence-sharded cores
    (``axis_name``), ring / sliding-window caches and non-group-major head
    maps silently keep the XLA path (the collectives and ring index math
    live only there)."""
    fused = impl in ("pallas", "pallas_interpret")
    if fused:
        H, kv_n = q.shape[-2], k_cache.shape[2]
        grouped = (H % kv_n == 0) and np.array_equal(
            np.asarray(kv_map), np.arange(H) // (H // kv_n))
        if axis_name is not None or window or ring or not grouped:
            fused = False
    if not fused:
        if q.ndim == 3:
            return flash_decode(q, k_cache, v_cache, lengths, kv_map=kv_map,
                                axis_name=axis_name, window=window, ring=ring,
                                scale=scale)
        return flash_decode_chunk(q, k_cache, v_cache, lengths, kv_map=kv_map,
                                  axis_name=axis_name, scale=scale)
    return fused_contiguous_attention(
        q, k_cache, lengths,
        v_cache=None if value_slice is not None else v_cache,
        value_slice=value_slice, scale=scale,
        interpret=(impl == "pallas_interpret"))
