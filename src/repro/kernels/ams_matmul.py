"""Fused AMS-Quant dequantize + matmul Pallas TPU kernel (paper §3.2/§3.3).

TPU adaptation of the paper's CUDA "fast restoration via bit operations":

  * packed int32 bit-planes stream HBM->VMEM through BlockSpec-tiled,
    grid-pipelined DMAs (the TPU analogue of coalesced global loads);
  * per-tile SHIFT/AND/OR restore sign/exponent/mantissa (+ shared LSB) into
    an f32 bit pattern in VREGs — no lookup tables, no scalar loops;
  * the restored bf16 tile feeds the MXU; f32 accumulation lives in a VMEM
    scratch across the K grid dimension; channel scales are folded in once
    at the final K step (they are per-output-channel, so they commute with
    the K-sum).

Grid: (B_blocks, N_blocks, K_blocks), K innermost ("arbitrary") so each
(b, n) accumulator is revisited consecutively; B/N are parallel.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import PackLayout

# renamed TPUCompilerParams -> CompilerParams in newer jax; same signature
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))
if _CompilerParams is None:  # pragma: no cover - future jax renames
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is unsupported by the AMS "
        "Pallas kernel")


# --------------------------------------------------------------------------
# In-kernel bit restoration (shared by both containers AND by the paged
# KV-cache attention kernel in repro.cache.paged_attention)
# --------------------------------------------------------------------------
def decode_codes_to_f32(codes: jnp.ndarray, fmt) -> jnp.ndarray:
    """SHIFT/AND/OR restoration of full codes -> f32 values (bit-exact)."""
    m, e, bias = fmt.man_bits, fmt.exp_bits, fmt.bias
    M = codes & ((1 << m) - 1)
    E = (codes >> m) & ((1 << e) - 1)
    S = (codes >> (m + e)) & 1
    sign_bits = S << 31
    # normal: reassemble an IEEE f32 bit pattern directly
    norm_bits = ((E - bias + 127) << 23) | (M << (23 - m)) | sign_bits
    v_norm = pltpu.bitcast(norm_bits.astype(jnp.int32), jnp.float32)
    # subnormal (E==0): value = M * 2^(1-bias-m); exact int->f32 convert
    v_sub = M.astype(jnp.float32) * np.float32(2.0 ** (1 - bias - m))
    v_sub = jnp.where(S == 1, -v_sub, v_sub)
    return jnp.where(E == 0, v_sub, v_norm)


def _unpack_planes(hi, lsb, lay: PackLayout, bk: int, bn: int) -> jnp.ndarray:
    """planes container -> full codes [bk, bn]."""
    k = lay.scheme.k
    hb, pw = lay.hi_bits, lay.per_word
    mask = (1 << hb) - 1
    parts = [(hi >> (hb * j)) & mask for j in range(pw)]
    hi_codes = jnp.stack(parts, axis=1).reshape(bk, bn)
    if k == 1:
        return hi_codes
    gbits = jnp.stack([(lsb >> j) & 1 for j in range(32)], axis=1)
    gbits = gbits.reshape(bk // k, 1, bn)
    lsb_full = jnp.broadcast_to(gbits, (bk // k, k, bn)).reshape(bk, bn)
    return (hi_codes << 1) | lsb_full


def _unpack_fp533(word, bk: int, bn: int) -> jnp.ndarray:
    """fp533 fused container -> full e2m3 codes [bk, bn].

    Each int32 = two half-words; each half = 3x5-bit high segments + 1 shared
    LSB (bit 15). 6 weights / word.
    """
    out = []
    for h in range(2):
        half = (word >> (16 * h)) & 0xFFFF
        shared = (half >> 15) & 1
        for j in range(3):
            out.append((((half >> (5 * j)) & 0x1F) << 1) | shared)
    codes = jnp.stack(out, axis=1)  # [bk//6, 6, bn] in position order
    return codes.reshape(bk, bn)


# --------------------------------------------------------------------------
# Kernel bodies
# --------------------------------------------------------------------------
def _kernel_planes(x_ref, hi_ref, lsb_ref, scale_ref, o_ref, acc_ref, *,
                   lay: PackLayout, bk: int, bn: int, nk: int, out_dtype):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack_planes(hi_ref[...], lsb_ref[...], lay, bk, bn)
    w = decode_codes_to_f32(codes, lay.scheme.base).astype(jnp.bfloat16)
    x = x_ref[...].astype(jnp.bfloat16)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(kb == nk - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * scale_ref[...].astype(jnp.float32)).astype(out_dtype)


def _kernel_fp533(x_ref, hi_ref, scale_ref, o_ref, acc_ref, *,
                  lay: PackLayout, bk: int, bn: int, nk: int, out_dtype):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack_fp533(hi_ref[...], bk, bn)
    w = decode_codes_to_f32(codes, lay.scheme.base).astype(jnp.bfloat16)
    x = x_ref[...].astype(jnp.bfloat16)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(kb == nk - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * scale_ref[...].astype(jnp.float32)).astype(out_dtype)


# --------------------------------------------------------------------------
# pallas_call wrapper
# --------------------------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=("lay", "B", "K", "N", "bb", "bk", "bn", "out_dtype", "interpret"),
)
def ams_matmul_padded(
    x, hi, lsb, scale, *, lay: PackLayout, B: int, K: int, N: int,
    bb: int, bk: int, bn: int, out_dtype=jnp.float32, interpret: bool = False,
):
    """Core pallas_call on pre-padded operands.

    x: [B, K] (B % bb == 0, K % bk == 0), hi/lsb padded to matching rows,
    scale: [1, N] (N % bn == 0).
    """
    nb, nn, nk = B // bb, N // bn, K // bk
    pw = lay.per_word
    hi_rows_per_bk = bk // pw

    x_spec = pl.BlockSpec((bb, bk), lambda b, n, k: (b, k))
    hi_spec = pl.BlockSpec((hi_rows_per_bk, bn), lambda b, n, k: (k, n))
    scale_spec = pl.BlockSpec((1, bn), lambda b, n, k: (0, n))
    out_spec = pl.BlockSpec((bb, bn), lambda b, n, k: (b, n))
    grid = (nb, nn, nk)
    scratch = [pltpu.VMEM((bb, bn), jnp.float32)]
    params = _CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary"),
    )

    if lay.container == "fp533":
        kernel = functools.partial(
            _kernel_fp533, lay=lay, bk=bk, bn=bn, nk=nk, out_dtype=out_dtype)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[x_spec, hi_spec, scale_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((B, N), out_dtype),
            scratch_shapes=scratch,
            compiler_params=params,
            interpret=interpret,
        )(x, hi, scale)

    k = lay.scheme.k
    if k > 1:
        lsb_spec = pl.BlockSpec((bk // (32 * k), bn), lambda b, n, kk: (kk, n))
    else:
        # dummy single-row plane, same block every step
        lsb_spec = pl.BlockSpec((1, bn), lambda b, n, kk: (0, n))
    kernel = functools.partial(
        _kernel_planes, lay=lay, bk=bk, bn=bn, nk=nk, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, hi_spec, lsb_spec, scale_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, N), out_dtype),
        scratch_shapes=scratch,
        compiler_params=params,
        interpret=interpret,
    )(x, hi, lsb, scale)
