"""Jitted public wrappers around the AMS-Quant Pallas kernels.

Handles shape normalization (leading batch dims, ragged B/K/N padding) so the
kernel only ever sees fully-tiled operands, then slices the result back.

Block shapes default to `kernels.tuning.plan_tiles` — the largest MXU-
aligned tile whose double-buffered working set fits the VMEM budget for the
actual (B, K, N) — with explicit ``block_*`` overrides taking precedence.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from repro.core.packing import PackedWeight
from . import ams_matmul as _k
from .tuning import plan_tiles


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def default_tiles(pw: PackedWeight, B: int):
    """The VMEM-budgeted `TilePlan` ams_matmul uses when no explicit block
    shapes are given (exposed for tests and tuning inspection)."""
    return plan_tiles(pw.layout, B, pw.K, pw.N)


def ams_matmul(
    x: jnp.ndarray,
    pw: PackedWeight,
    *,
    block_b: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    """y[..., N] = x[..., K] @ DeQ(W). Pallas path (set interpret=True on CPU)."""
    lay = pw.layout
    K, N = pw.K, pw.N
    lead = x.shape[:-1]
    # static shape math — jnp.prod here becomes a tracer under scan/jit
    B = math.prod(lead) if lead else 1
    x2 = x.reshape(B, x.shape[-1])

    if block_b is None or block_n is None or block_k is None:
        plan = plan_tiles(lay, B, K, N)
        block_b = plan.bb if block_b is None else block_b
        block_n = plan.bn if block_n is None else block_n
        block_k = plan.bk if block_k is None else block_k
    bk = block_k
    bb = min(block_b, _ceil_to(B, 8))
    bn = min(block_n, _ceil_to(N, 128))

    Bp, Kp, Np = _ceil_to(B, bb), _ceil_to(K, bk), _ceil_to(N, bn)
    x2 = jnp.pad(x2, ((0, Bp - B), (0, Kp - x2.shape[-1])))

    hi_rows = Kp // lay.per_word
    hi = jnp.pad(pw.hi, ((0, hi_rows - pw.hi.shape[0]), (0, Np - N)))
    k = lay.scheme.k
    if lay.container == "planes" and k > 1:
        lsb_rows = Kp // (32 * k)
        lsb = jnp.pad(pw.lsb, ((0, lsb_rows - pw.lsb.shape[0]), (0, Np - N)))
    else:
        lsb = jnp.zeros((1, Np), jnp.int32)
    scale = jnp.pad(pw.scale, (0, Np - N)).reshape(1, Np)

    y = _k.ams_matmul_padded(
        x2, hi, lsb, scale, lay=lay, B=Bp, K=Kp, N=Np,
        bb=bb, bk=bk, bn=bn, out_dtype=out_dtype, interpret=interpret,
    )
    return y[:B, :N].reshape(*lead, N)
