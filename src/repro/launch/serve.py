"""Serving driver: offline AMS-Quant PTQ -> prefill -> batched decode loop.

The paper's deployment scenario: weights are quantized/packed ahead of time
(§3.3 "Ahead-of-time weight packing"), then the decode loop streams packed
planes and restores on the fly. On CPU this runs reduced configs end to end
(quantized vs fp16 generations agree to high token-match rate — see
tests/test_serve_e2e.py); on a pod the same driver runs the production mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --scheme fp5.33-e2m3 --tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.core.policy import QuantPolicy
from repro.launch.steps import build_prefill_step, build_serve_step
from repro.launch.train import make_mesh
from repro.models import init_params, make_cache
from repro.models.common import quantize_params


def generate(arch: str, *, reduced=True, scheme="fp5.33-e2m3",
             strategy="set_lsb", impl="ref", mesh_kind="none",
             batch=2, prompt_len=16, gen_tokens=16, seed=0,
             params=None, capacity=None):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    cap = capacity or (prompt_len + gen_tokens + cfg.num_prefix_embeds)
    quant = None
    if scheme != "fp16":
        quant = QuantPolicy(scheme=scheme, strategy=strategy, impl=impl,
                            min_elements=1 << 10)
    rcfg = RunConfig(model=cfg, seq_len=cap, global_batch=batch,
                     mode="decode", quant=quant)
    mesh = make_mesh(mesh_kind)

    with jax.set_mesh(mesh):
        tp = mesh.shape["model"]
        if params is None:
            params = init_params(jax.random.PRNGKey(seed), cfg, tp=tp)
        params = jax.tree.map(
            lambda x: x.astype(jnp.bfloat16) if x.ndim >= 2 else x, params)
        if quant is not None:
            t0 = time.time()
            params = quantize_params(params, quant)
            print(f"[ptq] quantized to {scheme} ({strategy}) "
                  f"in {time.time()-t0:.1f}s", flush=True)

        # --- prefill on a prompt
        rng = np.random.default_rng(seed)
        prompt = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)
        prefix = None
        if cfg.num_prefix_embeds:
            prefix = jnp.asarray(rng.standard_normal(
                (batch, cfg.num_prefix_embeds, cfg.d_model)), jnp.float32)

        from repro.models import forward_seq, decode_step
        policy = quant
        logits, _, cache = forward_seq(
            params, prompt, cfg, tp=tp, policy=policy, want_cache=True,
            prefix_embeds=prefix, remat=False, dtype=jnp.bfloat16)
        # re-host prefill cache into the full-capacity decode cache
        big = make_cache(cfg, batch, cap, tp=tp, dtype=jnp.bfloat16)

        def into(b, s):
            if b.shape == s.shape:
                return s.astype(b.dtype)
            pads = [(0, x - y) for x, y in zip(b.shape, s.shape)]
            return jnp.pad(s.astype(b.dtype), pads)

        cache = jax.tree.map(into, big, cache)
        pos0 = prompt_len + cfg.num_prefix_embeds

        token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        out = [np.asarray(token)]
        lat = []
        step_jit = jax.jit(
            lambda p, t, c, q: decode_step(p, t, c, q, cfg, tp=tp,
                                           policy=policy,
                                           dtype=jnp.bfloat16),
            donate_argnums=(2,))
        for i in range(gen_tokens - 1):
            t0 = time.time()
            logits_i, cache = step_jit(params, token, cache,
                                       jnp.int32(pos0 + i))
            token = jnp.argmax(logits_i, axis=-1).astype(jnp.int32)
            token.block_until_ready()
            lat.append(time.time() - t0)
            out.append(np.asarray(token))
    toks = np.stack(out, axis=1)
    return toks, {"decode_ms_median": 1e3 * float(np.median(lat)) if lat else 0.0}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--scheme", default="fp5.33-e2m3")
    ap.add_argument("--strategy", default="set_lsb")
    ap.add_argument("--impl", default="ref")
    ap.add_argument("--mesh", default="none")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    toks, stats = generate(args.arch, reduced=args.reduced,
                           scheme=args.scheme, strategy=args.strategy,
                           impl=args.impl, mesh_kind=args.mesh,
                           batch=args.batch, prompt_len=args.prompt,
                           gen_tokens=args.tokens)
    print("generated tokens:\n", toks)
    print("stats:", stats)


if __name__ == "__main__":
    main()
