"""Serving driver: offline AMS-Quant PTQ -> continuous-batching decode.

The paper's deployment scenario: weights are quantized/packed ahead of time
(§3.3 "Ahead-of-time weight packing"), then the decode loop streams packed
planes and restores on the fly. Serving runs on the continuous-batching
engine in ``repro.launch.engine`` (``ServeEngine``): requests enter a FIFO
queue, a scheduler admits them into free KV-cache slots, and one jitted
slot-masked decode step serves all in-flight requests per tick.

``generate`` below is a thin fixed-batch wrapper over that engine, kept for
one-shot use and benchmarks (quantized vs fp16 generations agree to high
token-match rate — see tests/test_engine.py and tests/test_serve_quant.py).
On CPU this runs reduced configs end to end; on a pod the same step builder
carries the production mesh shardings.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --scheme fp5.33-e2m3 --tokens 32

For true streaming-arrival serving, construct ``ServeEngine`` directly (see
examples/serve_continuous.py and benchmarks/bench_serving.py).
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config
from repro.launch.engine import ServeEngine


def generate(arch: str, *, reduced=True, scheme="fp5.33-e2m3",
             strategy="set_lsb", impl="ref", mesh_kind="none",
             batch=2, prompt_len=16, gen_tokens=16, seed=0,
             params=None, capacity=None, prompts=None, prefix_embeds=None):
    """One-shot batched generation via the continuous-batching engine.

    Submits ``batch`` requests at tick 0 (prompts drawn from ``seed`` unless
    given explicitly as ``prompts`` [batch, prompt_len]) and drains the
    engine. Returns (tokens [batch, gen_tokens], stats).
    """
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()

    rng = np.random.default_rng(seed)
    if prompts is None:
        prompts = rng.integers(0, cfg.vocab_size, (batch, prompt_len))
    prompts = np.asarray(prompts, np.int32)
    batch, prompt_len = prompts.shape  # explicit prompts win over the kwargs
    cap = capacity or (prompt_len + gen_tokens + cfg.num_prefix_embeds)
    if cfg.num_prefix_embeds and prefix_embeds is None:
        prefix_embeds = rng.standard_normal(
            (batch, cfg.num_prefix_embeds, cfg.d_model)).astype(np.float32)

    eng = ServeEngine(arch, reduced=reduced, scheme=scheme, strategy=strategy,
                      impl=impl, mesh_kind=mesh_kind, slots=batch,
                      capacity=cap, seed=seed, params=params, verbose=True)
    reqs = [eng.submit(prompts[b], gen_tokens,
                       prefix_embeds=(prefix_embeds[b]
                                      if prefix_embeds is not None else None))
            for b in range(prompts.shape[0])]
    stats = eng.run()
    toks = np.stack([np.asarray(r.tokens, np.int32) for r in reqs])
    return toks, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--scheme", default="fp5.33-e2m3")
    ap.add_argument("--strategy", default="set_lsb")
    ap.add_argument("--impl", default="ref")
    ap.add_argument("--mesh", default="none")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    toks, stats = generate(args.arch, reduced=args.reduced,
                           scheme=args.scheme, strategy=args.strategy,
                           impl=args.impl, mesh_kind=args.mesh,
                           batch=args.batch, prompt_len=args.prompt,
                           gen_tokens=args.tokens)
    print("generated tokens:\n", toks)
    print("stats:", stats)


if __name__ == "__main__":
    main()
