"""Serving driver: offline AMS-Quant PTQ -> continuous-batching decode.

The paper's deployment scenario: weights are quantized/packed ahead of time
(§3.3 "Ahead-of-time weight packing"), then the decode loop streams packed
planes and restores on the fly. Serving runs on the continuous-batching
engine in ``repro.launch.engine`` (``ServeEngine``): requests enter a FIFO
queue, a scheduler admits them into free KV-cache slots, and one jitted
slot-masked decode step serves all in-flight requests per tick.

``generate`` below is a thin fixed-batch wrapper over that engine, kept for
one-shot use and benchmarks (quantized vs fp16 generations agree to high
token-match rate — see tests/test_engine.py and tests/test_serve_quant.py).
On CPU this runs reduced configs end to end; on a pod the same step builder
carries the production mesh shardings.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --scheme fp5.33-e2m3 --tokens 32

For true streaming-arrival serving, construct ``ServeEngine`` directly (see
examples/serve_continuous.py and benchmarks/bench_serving.py).
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config
from repro.launch.config import EngineConfig
from repro.launch.engine import ServeEngine


def generate(arch: str, *, reduced=True, scheme="fp5.33-e2m3",
             strategy="set_lsb", impl="ref", mesh_kind="none",
             batch=2, prompt_len=16, gen_tokens=16, seed=0,
             params=None, capacity=None, prompts=None, prefix_embeds=None,
             sampling=None):
    """One-shot batched generation via the continuous-batching engine.

    Submits ``batch`` requests at tick 0 (prompts drawn from ``seed`` unless
    given explicitly as ``prompts`` [batch, prompt_len]) and drains the
    engine. Returns (tokens [batch, gen_tokens], stats).

    ``sampling`` (a `repro.launch.sampling.SamplingParams`, or one per
    request) turns on per-request stochastic decoding; stop tokens can then
    end streams early, so the token array is padded with -1 past each
    stream's end. Default is greedy, bit-identical to earlier PRs.
    """
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()

    rng = np.random.default_rng(seed)
    if prompts is None:
        prompts = rng.integers(0, cfg.vocab_size, (batch, prompt_len))
    prompts = np.asarray(prompts, np.int32)
    batch, prompt_len = prompts.shape  # explicit prompts win over the kwargs
    cap = capacity or (prompt_len + gen_tokens + cfg.num_prefix_embeds)
    if cfg.num_prefix_embeds and prefix_embeds is None:
        prefix_embeds = rng.standard_normal(
            (batch, cfg.num_prefix_embeds, cfg.d_model)).astype(np.float32)

    eng = ServeEngine(
        EngineConfig(arch=arch, reduced=reduced, scheme=scheme,
                     strategy=strategy, impl=impl, mesh_kind=mesh_kind,
                     slots=batch, capacity=cap, seed=seed, verbose=True),
        params=params)
    per_req = (sampling if isinstance(sampling, (list, tuple))
               else [sampling] * prompts.shape[0])
    reqs = [eng.submit(prompts[b], gen_tokens,
                       prefix_embeds=(prefix_embeds[b]
                                      if prefix_embeds is not None else None),
                       sampling=per_req[b])
            for b in range(prompts.shape[0])]
    stats = eng.run()
    # stop tokens make streams ragged; pad the tail with -1 (never a token)
    width = max(r.n_generated for r in reqs)
    toks = np.full((len(reqs), width), -1, np.int32)
    for b, r in enumerate(reqs):
        toks[b, :r.n_generated] = r.tokens
    return toks, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--scheme", default="fp5.33-e2m3")
    ap.add_argument("--strategy", default="set_lsb")
    ap.add_argument("--impl", default="ref")
    ap.add_argument("--mesh", default="none")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy (default); >0 samples on-device")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--sample-seed", type=int, default=0)
    args = ap.parse_args()
    sampling = None
    if args.temperature > 0:
        from repro.launch.sampling import SamplingParams
        sampling = SamplingParams(temperature=args.temperature,
                                  top_k=args.top_k, top_p=args.top_p,
                                  seed=args.sample_seed)
    toks, stats = generate(args.arch, reduced=args.reduced,
                           scheme=args.scheme, strategy=args.strategy,
                           impl=args.impl, mesh_kind=args.mesh,
                           batch=args.batch, prompt_len=args.prompt,
                           gen_tokens=args.tokens, sampling=sampling)
    print("generated tokens:\n", toks)
    print("stats:", stats)


if __name__ == "__main__":
    main()
