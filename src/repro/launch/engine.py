"""Continuous-batching serving engine over the AMS-quantized model.

This is the paper's deployment story made a serving hot path instead of a
fixed-batch benchmark loop: weights are AMS-quantized/packed ahead of time
(`QuantPolicy` -> `quantize_params`), and ONE jitted slot-masked decode step
(`launch.steps.build_engine_step`) then serves every in-flight request per
tick, streaming the packed planes through `apply_linear`'s ``ref`` /
``fused_ref`` / ``pallas_interpret`` impls.

Architecture (Orca-style iteration-level scheduling):

  * the KV cache is either a fixed [slots, capacity] tensor (contiguous
    mode) or a POOL of fixed-size pages addressed through per-request
    block tables (`repro.cache`, paged-bf16 / paged-AMS modes — the AMS
    pool stores each K/V vector in the paper's packed e2m2 planes,
    quantized once at insert). Each slot holds one request with its own
    position counter (`decode_step` takes [B] per-slot positions;
    negative = idle slot, cache write suppressed);
  * a priority scheduler (`launch.scheduler`; all-default priorities =
    strict FIFO) admits queued requests into freed slots; admission is
    capacity-checked at submit time (contiguous) or gated on the free-PAGE
    budget at admit time (paged — short requests reserve only their own
    pages, not worst-case slots);
  * PREEMPTION (paged modes, ``EngineConfig.preempt``): when the queue
    head outranks the lowest-priority active request and cannot admit, the
    engine preempts that victim — its private pages' content spills to
    host memory in the pool's PACKED layout (`cache.pool.extract_pages`;
    AMS planes byte-exact), its shared prefix pages stay pinned
    (refcounts held), and it re-queues ahead of its priority class. On
    re-admission the engine restores the spilled pages into fresh device
    pages and resumes feeding at the exact spilled position — never
    re-prefilling — so preempted streams are bit-identical to
    uninterrupted ones (seeded draws fold only (rid, token index), never
    slot or tick). Below eviction sits the optional host spill tier
    (`CacheConfig.host_spill_pages`): LRU-evicted published pages offload
    host-side and restore on a later prefix hit instead of re-prefilling;
  * completed PROMPT pages are PREFIX-CACHED across requests (paged modes,
    on by default; ``CacheConfig(prefix_cache=False)`` disables): each full
    prompt page is content-addressed by a prefix-chain hash, and a request
    whose prompt shares a cached page-aligned prefix references the SAME
    physical pages (refcounted, read-only) and starts prefill at the cached
    length — a shared 1k-token system prompt prefills once, not once per
    request. Admission charges only the uncached page count; refcount-0
    cached pages stay resident in an LRU until memory pressure evicts them.
    Reuse is bit-exact because the pool's insert quantization is
    deterministic per (token, head);
  * prefill is CHUNKED INTO THE DECODE BATCH as a RAGGED MULTI-TOKEN STEP:
    each tick, every active slot contributes a variable-length block of up
    to ``prefill_chunk`` tokens — prefilling slots consume a prompt chunk
    (and any modality prefix embeddings), decoding slots consume 1 — all
    executed as ONE jitted program (`launch.steps.build_engine_step` with
    ``chunk=C``). Logits are taken in-step at each slot's last valid
    token, so time-to-first-token scales with ceil(prompt/C) ticks instead
    of prompt length. A global per-tick TOKEN BUDGET caps the chunk total;
    every active slot is guaranteed one token per tick and admission is
    budget-aware (`FIFOScheduler.admit(max_admit=...)`), so a long prefill
    can never starve decode slots. One program, no separate prefill
    compilation, no batch-shape churn. (``prefill_chunk=1`` — the default,
    and the only mode for recurrent-state families — degenerates to the
    original one-position-per-tick step.);
  * sampling is ON-DEVICE and PER-REQUEST (`repro.launch.sampling`): each
    request carries a `SamplingParams(temperature, top_k, top_p, seed,
    max_tokens, stop_token_ids)`; the step applies the logit transforms
    and categorical draw from per-slot folded PRNG keys and decides
    termination (stop-token hit or length cap) in-step, so only [B] int32
    tokens + [B] done bools cross to the host per tick. ``temperature=0``
    (the default) lowers to the exact argmax path, keeping every greedy
    stream-equivalence guarantee bit-identical. Seeded streams replay
    bit-identically across engine restarts and slot reassignment: the
    draw key folds in the REQUEST id and the request's own token index,
    never the slot or tick. A finished slot frees its pages (prefix pages
    stay published per the refcount semantics above) and the queue is
    re-polled the SAME tick, so early EOS turns directly into admission
    headroom;
  * SPECULATIVE DECODING rides the same ragged step
    (`launch.speculative`, ``speculate_k=k`` + ``drafter``): on
    pure-decode rounds a cheap host drafter proposes up to k tokens per
    slot, the step feeds ``[last_token, d_1..d_k]`` so ONE pass scores
    every draft, and an on-device verify epilogue accepts the longest
    correct prefix, draws the bonus/corrective token, terminates in-step,
    and zero-scatters rejected KV entries back to pool-initial state —
    the engine then rewinds its feed position (never past the prompt, so
    shared prefix pages are structurally untouchable). Greedy streams
    stay bit-identical to non-speculative decoding; a round emits 1..k+1
    tokens per model pass (``stats()``: ``accept_rate`` /
    ``tokens_per_step``). See docs/speculative.md;
  * OBSERVABILITY is first-class (`repro.obs`, ``obs=ObsConfig(...)``):
    the engine owns a metrics registry every subsystem (scheduler,
    allocator, drafter) emits into, and ``stats()`` is computed from it
    (bit-identical to the historical hand counters); ``ObsConfig(trace=
    True)`` records per-request lifecycle + per-tick device-step spans as
    a Perfetto-loadable Chrome trace, and the roofline cost model
    (`obs.cost`) attributes analytic floor HBM bytes/FLOPs to every tick
    and request. ``ObsConfig(enabled=False)`` swaps in no-op instruments —
    telemetry cannot perturb the measured system. See
    docs/observability.md.

Because every slot's computation is row-independent (attention hard-masks
invalid cache positions to exact zeros), a request's token stream is
identical whether it runs alone or packed against arbitrary neighbours —
``tests/test_engine.py`` pins this batch-invariance against the one-shot
``launch.serve.generate`` path. (MoE configs are the exception: capacity-
based expert routing couples tokens across the batch.)

Quickstart (the stable facade is `repro.serving`)::

    from repro.serving import EngineConfig, ServeEngine

    eng = ServeEngine(EngineConfig(arch="qwen2-7b", scheme="fp5.33-e2m3",
                                   slots=4, capacity=64))
    handle = eng.submit(np.array([1, 2, 3]), max_tokens=16)
    print(handle.result())

The legacy keyword constructor (``ServeEngine("qwen2-7b", slots=4, ...)``)
still works via `EngineConfig.from_legacy` with a DeprecationWarning, and
is pinned to an identical `engine_step_signature`. The driver loop can be
a plain ``eng.run()``, a per-handle ``handle.result()``, or the asyncio
HTTP/SSE front end (`repro.launch.frontend`) which overlaps host-side
request intake/streaming with the device step via `step_begin`/`step_end`.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import (
    CacheConfig,
    PageAllocator,
    compression_vs_bf16,
    extract_pages,
    host_bytes,
    prefix_page_hashes,
    restore_pages,
)
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.core.policy import QuantPolicy
from repro.launch.config import EngineConfig
from repro.launch.mesh import make_driver_mesh, use_mesh
from repro.launch.sampling import (
    GREEDY,
    SamplingParams,
    clear_slot,
    fill_slot,
    request_key,
    slot_batch,
)
from repro.launch.scheduler import (
    DECODE,
    FINISHED,
    PREEMPTED,
    PREFILL,
    FIFOScheduler,
    Request,
    SpilledState,
)
from repro.launch.steps import build_engine_step, engine_step_signature
from repro.models import init_params, make_cache, model_dims, reset_cache_slot
from repro.models.common import quantize_params
from repro.obs import MetricsRegistry, ObsConfig, TraceRecorder, build_cost_model
from repro.obs.metrics import COUNT_BUCKETS, NULL_REGISTRY, TIME_BUCKETS


class RequestHandle:
    """Client-facing view of a submitted request — the ONLY object
    `ServeEngine.submit` returns. It exposes the stable read surface
    (`.status`, `.tokens_so_far()`, `.result()`, async `.stream()`) and
    transparently forwards every other attribute read to the underlying
    `Request` record, so existing code that inspected `.tokens`, `.done`,
    `.ttft_ticks`, ... keeps working while new code never touches Request
    internals."""

    __slots__ = ("_req", "_eng")

    def __init__(self, req: Request, engine: "ServeEngine"):
        object.__setattr__(self, "_req", req)
        object.__setattr__(self, "_eng", engine)

    @property
    def request(self) -> Request:
        """The underlying scheduler record (escape hatch; internals)."""
        return self._req

    @property
    def status(self) -> str:
        """Lifecycle: queued -> prefill -> decode -> finished, with
        preempted as the spilled-out detour (scheduler.REQUEST_STATUSES)."""
        return self._req.status

    @property
    def done(self) -> bool:
        return self._req.done

    def tokens_so_far(self) -> List[int]:
        """Snapshot of the tokens generated so far (a copy)."""
        return list(self._req.tokens)

    def result(self, max_ticks: int = 1_000_000) -> List[int]:
        """Block until this request finishes and return its full token
        list. When no driver loop is running this drives the engine
        itself; when one is (``engine.driver_active``, e.g. the async
        frontend), it waits on the engine's tick signal instead."""
        eng, req = self._eng, self._req
        for _ in range(max_ticks):
            if req.done:
                break
            if eng.driver_active:
                eng.wait_tick(eng.tick)
            elif eng.has_work:
                eng.step()
            else:          # pragma: no cover - submitted but queue dropped
                break
        return list(req.tokens)

    async def stream(self):
        """Async token stream (the SSE feed): yields each generated token
        id as it lands, finishing when the request does. Drives the engine
        from a worker thread when no driver loop is active; otherwise
        waits on the engine's tick signal so any number of streams ride
        one driver."""
        import asyncio
        eng, req = self._eng, self._req
        sent = 0
        while True:
            while sent < len(req.tokens):
                tok = int(req.tokens[sent])
                sent += 1
                yield tok
            if req.done:
                return
            if eng.driver_active:
                await asyncio.to_thread(eng.wait_tick, eng.tick)
            else:
                await asyncio.to_thread(eng.step)

    def __getattr__(self, name):
        return getattr(self._req, name)

    def __repr__(self):
        r = self._req
        return (f"RequestHandle(rid={r.rid}, status={r.status!r}, "
                f"tokens={len(r.tokens)})")


@dataclasses.dataclass
class _PendingStep:
    """In-flight device step between `step_begin` and `step_end` (the
    double-buffering seam: the host is free while the device computes)."""

    outs: Any                 # un-awaited step outputs (async dispatch)
    nvalid: np.ndarray
    ndraft: np.ndarray
    t0: float
    fed: int
    tracing: bool
    idle: bool = False
    result: Optional[Dict[str, object]] = None   # idle ticks resolve early


class ServeEngine:
    """Slot-based continuous-batching engine (see module docstring)."""

    def __init__(self, config: Any = None, *, params=None, **legacy):
        # THE constructor surface is one frozen EngineConfig (every
        # validation already ran in its __post_init__ — the single error
        # surface). The pre-redesign keyword form ServeEngine(arch,
        # slots=..., ...) routes through the from_legacy deprecation shim,
        # pinned to an identical engine_step_signature. `params` stays a
        # direct argument: it is runtime state (weights), not config.
        if isinstance(config, EngineConfig):
            if legacy:
                raise TypeError(
                    f"ServeEngine(EngineConfig, ...) takes no extra "
                    f"keyword arguments, got {sorted(legacy)}")
            ec = config
        else:                     # legacy: positional arch string (or None)
            ec = EngineConfig.from_legacy(config, **legacy)
        self.config = ec
        cfg = get_config(ec.arch)
        if ec.reduced:
            cfg = cfg.reduced()
        self.cfg = cfg
        scheme = self.scheme = ec.scheme
        slots = self.slots = ec.slots
        capacity = self.capacity = ec.capacity
        self.chunk = ec.prefill_chunk   # chunk support is gated by
        #                              build_engine_step(check_chunked_support)
        self.speculate_k = ec.speculate_k
        # the jitted step's chunk width must hold 1 fed token + k drafts
        # per slot; prefill growth stays capped at prefill_chunk
        self.step_chunk = ec.step_chunk
        # per-tick token budget: every active slot is guaranteed 1; prefill
        # chunks and draft blocks grow only into the leftover. Default = no
        # throttling.
        self.token_budget = ec.resolved_token_budget
        ccfg = self.cache_cfg = ec.sized_cache()
        # preemption needs pages to spill — contiguous caches run the
        # PR 1-9 no-preemption policy regardless of the flag
        self.preempt_enabled = bool(ec.preempt and ccfg.paged)
        # observability (repro.obs): one registry per engine, resolved to
        # the shared no-op instruments when disabled — recording can never
        # perturb the measured system (bench --obs-check asserts 0% drift)
        self.obs = ec.obs
        self.metrics = (MetricsRegistry() if self.obs.enabled
                        else NULL_REGISTRY)
        self.trace = TraceRecorder(enabled=self.obs.trace_on)
        self.trace.thread(0, "engine")
        quant = None
        if scheme != "fp16":
            quant = QuantPolicy(scheme=scheme, strategy=ec.strategy,
                                impl=ec.impl, min_elements=1 << 10)
        self.rcfg = RunConfig(model=cfg, seq_len=capacity, global_batch=slots,
                              mode="decode", quant=quant)
        # tensor-parallel serving: pass an explicit mesh (e.g.
        # mesh.make_serving_mesh(tp)) and the jitted step runs sharded —
        # weight planes placed by the serving layout, paged pools
        # head-sharded over the model axis, token streams bit-identical to
        # the single-device engine. Default: the mesh_kind driver mesh
        # (1x1 for "none").
        self.mesh = ec.mesh if ec.mesh is not None \
            else make_driver_mesh(ec.mesh_kind)
        seed, drafter, verbose = ec.seed, ec.drafter, ec.verbose

        with use_mesh(self.mesh):
            tp = self.mesh.shape["model"]
            if params is None:
                params = init_params(jax.random.PRNGKey(seed), cfg, tp=tp)
            params = jax.tree.map(
                lambda x: x.astype(jnp.bfloat16) if x.ndim >= 2 else x, params)
            if quant is not None:
                t0 = time.time()
                params = quantize_params(params, quant)
                if verbose:
                    print(f"[ptq] quantized to {ec.scheme} ({ec.strategy}) "
                          f"in {time.time()-t0:.1f}s", flush=True)
            self.params = params
            # the CacheConfig threads through for contiguous caches too:
            # its impl field routes the decode cores through the fused
            # attention template (kernels.attention_template)
            self.cache = make_cache(cfg, slots, capacity, tp=tp,
                                    dtype=jnp.bfloat16, cache_cfg=ccfg)
            # per-device KV residency: a head-sharded paged pool (kv heads
            # divide the model axis — the same rule steps.py/pool_shardings
            # apply) keeps 1/tp of the pool on each device, so all
            # kv-bytes-per-token accounting below is PER DEVICE
            _dims = model_dims(cfg, tp)
            self._kv_shards = (tp if (ccfg.paged and tp > 1
                                      and _dims.kv % tp == 0) else 1)
            # arg shapes are kept for obs.cost.hlo_step_cost: lowering the
            # jitted step at its serving shapes yields the compiled
            # program's achieved per-tick HBM/FLOP cost
            self._step, self._step_shapes, _shardings = build_engine_step(
                self.mesh, cfg, self.rcfg, cache_cfg=ccfg,
                chunk=self.step_chunk, sampling=True,
                speculate_k=self.speculate_k)
            # host->device spill restores happen OUTSIDE the jitted step;
            # on a tp>1 mesh the restored cache must be re-placed to the
            # step's expected sharding before the next dispatch
            self._cache_sharding = _shardings.get("cache")
            # the drafter proposes from the (possibly quantized) serving
            # params — resolved here so "self" binds the engine's own stack
            self.drafter = None
            if self.speculate_k:
                from repro.launch.speculative import Drafter, make_drafter
                if isinstance(drafter, str):
                    drafter = make_drafter(drafter, params=params, cfg=cfg,
                                           capacity=capacity, tp=tp,
                                           policy=quant)
                if not isinstance(drafter, Drafter):
                    raise TypeError(f"drafter must be a Drafter or name, "
                                    f"got {type(drafter).__name__}")
                self.drafter = drafter
                self.drafter.bind_metrics(self.metrics)
            # paged pools need no per-slot reset: positions are written
            # front-to-front per request, so every valid key is fresh, and
            # recurrent-state families are rejected by check_paged_support
            self._reset = (None if ccfg.paged else
                           jax.jit(reset_cache_slot, donate_argnums=(0,)))

        # host-side slot state
        if ccfg.paged:
            self.alloc: Optional[PageAllocator] = PageAllocator(
                ccfg.num_pages, ccfg.page_size, metrics=self.metrics,
                host_spill_pages=ccfg.host_spill_pages)
            # the eviction-spill hook: reads the CURRENT cache pytree at
            # eviction time (self.cache rebinds functionally every tick)
            self.alloc.spill_fn = \
                lambda page: extract_pages(self.cache, [page])
            self.block_tables = np.zeros(
                (slots, ccfg.max_pages_per_seq), np.int32)
            # a request can never outgrow its block-table row or the pool
            eff_cap = min(ccfg.max_pages_per_seq, ccfg.num_pages) * ccfg.page_size
        else:
            self.alloc = None
            self.block_tables = None
            eff_cap = capacity
        self.sched = FIFOScheduler(eff_cap, max_queue=ec.max_queue,
                                   metrics=self.metrics)
        self.active: List[Optional[Request]] = [None] * slots
        self.fed = np.zeros(slots, np.int32)   # inputs consumed == insert pos
        self.last_token = np.zeros(slots, np.int32)
        # per-slot sampling state shipped to the step each tick (key, ngen,
        # temperature, top_k, top_p, max_tokens, stop_ids rows)
        self.samp = slot_batch(slots)
        self.tick = 0
        self.finished: List[Request] = []
        self._rid = itertools.count()
        # preemption accounting (plain ints: real state, registry-
        # independent, like PageAllocator.hits)
        self.preemptions = 0       # requests preempted (spilled out)
        self.resumes = 0           # preempted requests re-admitted
        self.spill_pages = 0       # pages whose content spilled host-side
        self.spill_bytes = 0       # host bytes those spills occupied
        # double-buffered dispatch seam: at most ONE device step in flight
        self._pending: Optional[_PendingStep] = None
        # tick signal for concurrent waiters (RequestHandle.result/stream
        # under an external driver loop, e.g. the async frontend)
        self._tick_cv = threading.Condition()
        self.driver_active = False
        # serializes frontend-thread submit() against the driver thread's
        # admission/preemption pass (RLock: _admit -> preempt -> requeue)
        self._queue_lock = threading.RLock()

        # --- telemetry instruments, resolved ONCE (recording on the tick
        # path is then a plain float add; all of stats() derives from
        # these — the two tick histograms keep raw observations in
        # insertion order so the legacy percentile math is bit-identical)
        m = self.metrics
        self.signature = engine_step_signature(
            cfg, self.rcfg, cache_cfg=ccfg,
            chunk=self.step_chunk, speculate_k=self.speculate_k,
            mesh=self.mesh)
        m.gauge("serve_step_signature_info",
                "engine-step signature (value is always 1)",
                tuple(self.signature)).labels(**self.signature).set(1)
        self._m_tick_s = m.histogram(
            "serve_tick_seconds", "wall seconds per served (non-idle) tick",
            buckets=TIME_BUCKETS)
        self._m_tick_tok = m.histogram(
            "serve_tick_tokens", "tokens emitted per served tick",
            buckets=COUNT_BUCKETS)
        self._m_idle = m.counter("serve_idle_ticks_total",
                                 "ticks with no active slot")
        self._m_steps = m.counter("serve_device_steps_total",
                                  "jitted engine-step invocations")
        self._m_fed = m.counter("serve_tokens_fed_total",
                                "input positions fed through the step")
        self._m_chunk = m.histogram(
            "serve_chunk_tokens", "tokens fed per active slot per tick",
            buckets=COUNT_BUCKETS, keep_raw=False)
        self._m_finished = m.counter("serve_requests_finished_total",
                                     "finished requests, by reason",
                                     ("reason",))
        self._m_fin_stop = self._m_finished.labels(reason="stop")
        self._m_fin_len = self._m_finished.labels(reason="length")
        self._m_prompt = m.counter("serve_prompt_tokens_total",
                                   "prompt positions admitted")
        self._m_cached = m.counter("serve_cached_prompt_tokens_total",
                                   "prompt positions served from shared pages")
        self._m_preempt = m.counter("serve_preemptions_total",
                                    "requests preempted (pages spilled)")
        self._m_resume = m.counter("serve_resumes_total",
                                   "preempted requests re-admitted")
        self._m_spill_pages = m.counter(
            "serve_spill_pages_total",
            "private pages spilled host-side at preemption")
        self._m_restore_pages = m.counter(
            "serve_restore_pages_total",
            "spilled pages restored into fresh device pages")
        self._m_spill_bytes = m.counter(
            "serve_spill_bytes_total",
            "host bytes occupied by preemption spills")
        self._m_spec_prop = m.counter("serve_spec_proposed_total",
                                      "draft tokens scored by the step")
        self._m_spec_acc = m.counter("serve_spec_accepted_total",
                                     "draft tokens accepted by the verify")
        self._m_emit = m.counter("serve_emit_rounds_total",
                                 "slot-rounds that emitted tokens")
        self._m_ttft = m.histogram("serve_request_ttft_ticks",
                                   "submit -> first token, engine ticks",
                                   buckets=COUNT_BUCKETS)
        self._m_lat = m.histogram("serve_request_latency_ticks",
                                  "submit -> finish, engine ticks",
                                  buckets=COUNT_BUCKETS)
        self._m_glen = m.histogram("serve_request_gen_tokens",
                                   "tokens generated per finished request",
                                   buckets=COUNT_BUCKETS)
        self._m_active = m.gauge("serve_active_slots",
                                 "slots serving a request")
        m.gauge("serve_queue_depth", "requests waiting for a slot",
                fn=lambda: self.sched.queue_depth)

        # --- roofline attribution (obs.cost): analytic floors for this
        # step signature; per-tick accounting runs in step()
        self.cost_model = None
        if self.obs.cost_on:
            dims = model_dims(cfg, self.mesh.shape["model"])
            self.cost_model = build_cost_model(
                cfg, scheme, ccfg,
                kv=dims.kv, hd=dims.hd, tp=self.mesh.shape["model"],
                kv_shards=self._kv_shards, signature=self.signature)
            self._kv_bpt = float(self.kv_bytes_per_token())
            self._m_floor_b = m.counter(
                "serve_floor_hbm_bytes_total",
                "analytic floor HBM bytes (weights + causal KV)")
            self._m_floor_f = m.counter("serve_floor_flops_total",
                                        "analytic floor FLOPs")
            self._m_kv_floor = m.counter(
                "serve_kv_floor_bytes_total",
                "causal-floor KV bytes (writes + attended reads)")
            self._m_kv_ach = m.counter(
                "serve_kv_achieved_bytes_total",
                "KV bytes the cache implementation touches")

        # jax.profiler capture of the first obs.jax_profile_ticks served
        # ticks (XLA-level trace; ObsConfig.jax_profile_dir)
        self._prof_ticks_left = (self.obs.jax_profile_ticks
                                 if self.obs.enabled else 0)
        self._prof_active = False

    # ------------------------------------------------------------- frontend
    def submit(self, prompt, max_tokens: Optional[int] = None,
               prefix_embeds=None,
               sampling: Optional[SamplingParams] = None,
               priority: int = 0) -> RequestHandle:
        """Enqueue a request and return its `RequestHandle` (`.status`,
        `.tokens_so_far()`, `.result()`, async `.stream()`). Raises if it
        can never fit a cache slot. (`Request.__post_init__` normalizes
        the prompt to [P] int32.)

        ``sampling`` configures the per-request draw (temperature/top_k/
        top_p/seed) and termination (stop_token_ids + max_tokens); omitted
        -> greedy argmax, exactly the PR 1-4 behaviour. ``max_tokens`` is
        the length CAP — ``sampling.max_tokens`` wins when both are given,
        and a stop-token hit ends the stream earlier. ``priority`` (higher
        = more urgent, default 0) orders the queue and — paged modes with
        ``EngineConfig.preempt`` — lets a blocked high-priority head spill
        a lower-priority active request out to host memory."""
        sp = sampling if sampling is not None else GREEDY
        if sp.max_tokens is not None:
            max_tokens = sp.max_tokens
        if max_tokens is None:
            raise ValueError(
                "max_tokens required (argument or SamplingParams.max_tokens)")
        if prefix_embeds is not None:
            prefix_embeds = np.asarray(prefix_embeds, np.float32)
            if self.cfg.num_prefix_embeds == 0:
                raise ValueError(
                    f"{self.cfg.name} has no modality frontend; "
                    "prefix_embeds unsupported")
            if (prefix_embeds.ndim != 2
                    or prefix_embeds.shape[1] != self.cfg.d_model):
                raise ValueError(
                    f"prefix_embeds must be [n, d_model={self.cfg.d_model}], "
                    f"got {prefix_embeds.shape}")
        # the queue lock serializes frontend-thread submissions against the
        # driver thread's admission pass (heap push vs pop)
        with self._queue_lock:
            rid = next(self._rid)
            # request-level PRNG key: seed + REQUEST id (never the
            # slot/tick), so seeded streams replay across restarts and
            # slot reassignment
            req = Request(rid=rid, prompt=prompt, max_tokens=max_tokens,
                          prefix_embeds=prefix_embeds, sampling=sp,
                          key_data=request_key(sp.seed, rid),
                          priority=priority)
            ccfg = self.cache_cfg
            if ccfg.paged and ccfg.prefix_cache and prefix_embeds is None:
                # chain hash per FULL prompt page — the prefix-cache
                # identity (modality prefixes are request-local floats, not
                # hashable token pages, so VLM/audio requests skip the
                # cache)
                req.page_hashes = prefix_page_hashes(
                    req.prompt, ccfg.page_size, ccfg.content_key)
            self.sched.submit(req, self.tick)     # raises on backpressure
            if self.trace.enabled:
                # one trace thread per request (tid 0 is the engine): the
                # request span opens here and closes at finish; "queued"
                # runs until admission
                self.trace.thread(rid + 1, f"req {rid}")
                self.trace.begin(rid + 1, "request",
                                 args={"prompt_len": req.prompt_len,
                                       "max_tokens": max_tokens})
                self.trace.begin(rid + 1, "queued")
        return RequestHandle(req, self)

    @property
    def has_work(self) -> bool:
        return any(r is not None for r in self.active) or len(self.sched) > 0

    @property
    def active_count(self) -> int:
        return sum(r is not None for r in self.active)

    # ------------------------------------------------------------ admission
    def _admit(self) -> int:
        """Admit queued requests into free slots; returns the count placed.

        Contiguous: reset slot caches first — recurrent SSM/RG-LRU states
        integrate garbage while a slot idles; KV entries are position-
        masked but cleared too. Paged: reserve the request's worst-case
        pages and publish its block-table row instead; admission is
        additionally gated on the free-page budget via `fits`. Admission
        is token-budget-aware: active slots never exceed the per-tick
        budget, so every slot advances every tick.

        Called at tick START and AGAIN after slots free at tick end, so an
        early-terminating (stop-token) request's capacity becomes an
        admission the same tick it finishes.

        PREEMPTION POLICY (paged + `EngineConfig.preempt`): after normal
        admission, while the queue head STRICTLY outranks the lowest-
        priority active request and remains blocked, that victim (ties:
        latest admitted) is preempted — spilled host-side and requeued —
        and admission re-runs. Strictness means a requeued request can
        never evict its own priority class, so there is no ping-pong.
        """
        with self._queue_lock:
            return self._admit_locked()

    def _admit_locked(self) -> int:
        paged = self.cache_cfg.paged
        fits = None
        if paged:
            ps = self.cache_cfg.page_size

            # cache-aware admission: the longest resident prefix of the
            # request's page hashes is SHARED (pinned, read-only) and
            # only the uncached page count charges the free budget.
            # Allocation happens right here, inside the check — admit's
            # contract (fits(head) True => head is admitted) makes the
            # mutation safe, and it keeps the budget exact when one
            # tick both pins cached pages and evicts cold ones.
            def fits(r):
                need = self.alloc.pages_needed(r.kv_need)
                if r.spill is not None:
                    # resume: the kept shared prefix is still pinned, so
                    # only the extension charges the budget; the spilled
                    # content is restored right after placement
                    if not self.alloc.can_resume(r.rid, need):
                        return False
                    r.pages = r.pages + self.alloc.resume(r.rid, need)
                    return True
                # always re-feed at least the last prompt token (its
                # logits produce the first generated token), so the
                # matchable prefix stops one position short of the end
                hashes = r.page_hashes[
                    : (r.n_prefix + r.prompt_len - 1) // ps]
                if not self.alloc.can_alloc(need, hashes):
                    return False
                r.pages, shared = self.alloc.alloc(r.rid, need, hashes)
                r.cached_len = shared * ps
                r.published = shared
                return True

        def admit_now():
            free = [s for s, r in enumerate(self.active) if r is None]
            room = self.token_budget - self.active_count
            return self.sched.admit(free, self.tick, fits=fits,
                                    max_admit=max(0, room))

        n = self._place(admit_now())
        if self.preempt_enabled:
            while True:
                head = self.sched.head
                if head is None:
                    break
                victims = [(r.priority, -r.admit_tick, s)
                           for s, r in enumerate(self.active)
                           if r is not None]
                if not victims:
                    break
                pri, _, victim_slot = min(victims)
                if head.priority <= pri:
                    break      # strict: equals never evict each other
                self.preempt(victim_slot)
                n += self._place(admit_now())
        return n

    def _place(self, placed) -> int:
        """Per-request placement bookkeeping for `sched.admit` results:
        block-table row / slot reset, trace span flip, sampling-row fill,
        and — for resumed requests — the spilled-state restore."""
        paged = self.cache_cfg.paged
        if paged and self.alloc.pending_restores:
            # host-tier prefix hits: admission matched hashes whose pages
            # were evicted to host memory; scatter their packed content
            # back into the fresh pages before any of them is read
            pr, self.alloc.pending_restores = self.alloc.pending_restores, []
            ids = [p for p, _ in pr]
            host = jax.tree.map(
                lambda *ls: np.concatenate(ls, axis=ls[0].ndim - 4),
                *[c for _, c in pr])
            self.cache = restore_pages(self.cache, ids, host)
            if self.mesh.shape["model"] > 1 \
                    and self._cache_sharding is not None:
                self.cache = jax.device_put(self.cache, self._cache_sharding)
            self._m_restore_pages.inc(len(ids))
        for slot, req in placed:
            resumed = req.spill is not None
            if paged:
                self.block_tables[slot] = self.alloc.block_table_row(
                    req.rid, self.block_tables.shape[1])
                if not resumed:
                    self._m_cached.inc(req.cached_len)
            else:
                self.cache = self._reset(self.cache, slot)
            if not resumed:
                self._m_prompt.inc(req.n_prefix + req.prompt_len)
            if self.trace.enabled:
                self.trace.end(req.rid + 1,
                               "preempted" if resumed else "queued",
                               args={"slot": slot,
                                     "cached_len": req.cached_len})
                self.trace.begin(req.rid + 1,
                                 "decode" if (resumed and req.tokens)
                                 else "prefill")
            self.active[slot] = req
            # prefill skip: cached pages already hold positions
            # [0, cached_len), so this slot starts feeding there
            self.fed[slot] = req.cached_len
            fill_slot(self.samp, slot, req.sampling, req.key_data,
                      req.max_tokens)
            req.status = PREFILL
            if resumed:
                self._restore_slot(slot, req)
        return len(placed)

    def _restore_slot(self, slot: int, req: Request) -> None:
        """Scatter a resumed request's spilled page content into its fresh
        pages and rewind slot state to the exact spilled position. The
        restored planes are byte-identical (packed AMS round trip) and the
        sampling key folds only (rid, token index) with ``ngen`` restored
        below, so the continued stream is bit-identical to one that was
        never preempted — and nothing is ever re-prefilled."""
        sp = req.spill
        if sp.n_pages:
            new_pages = req.pages[sp.n_keep:sp.n_keep + sp.n_pages]
            self.cache = restore_pages(self.cache, new_pages, sp.content)
            if self.mesh.shape["model"] > 1 \
                    and self._cache_sharding is not None:
                # outside-jit scatters can drop the head-sharded layout;
                # re-place so the next dispatch sees its expected sharding
                self.cache = jax.device_put(self.cache, self._cache_sharding)
            self._m_restore_pages.inc(sp.n_pages)
        self.fed[slot] = sp.fed
        self.last_token[slot] = sp.last_token
        self.samp["ngen"][slot] = req.n_generated
        # re-publish restored prompt pages from the kept-prefix boundary:
        # publish() is a no-op wherever the original page is still resident
        req.published = sp.n_keep
        req.status = DECODE if req.tokens else PREFILL
        req.spill = None
        self.resumes += 1
        self._m_resume.inc()

    def preempt(self, slot: int) -> Request:
        """Preempt the request in `slot`: snapshot its private pages'
        content host-side (packed planes — `cache.pool.extract_pages`),
        release those pages (shared prefix stays pinned), clear the slot,
        and requeue the request ahead of its priority class. Public so
        tests can force preemption at arbitrary stream positions; the
        engine's own policy calls this from `_admit`."""
        req = self.active[slot]
        if req is None:
            raise ValueError(f"slot {slot} is idle")
        if not self.cache_cfg.paged:
            raise RuntimeError("preemption requires a paged cache")
        ps = self.cache_cfg.page_size
        fed = int(self.fed[slot])
        n_keep = req.cached_len // ps            # shared prefix: pinned
        n_touched = -(-fed // ps)                # pages holding content
        spill_ids = req.pages[n_keep:n_touched]
        content = extract_pages(self.cache, spill_ids) if spill_ids else None
        nbytes = host_bytes(content) if spill_ids else 0
        # snapshot BEFORE release: a released page may be reused by the
        # very next alloc
        self.alloc.preempt(req.rid, n_keep)
        req.pages = req.pages[:n_keep]
        req.spill = SpilledState(
            fed=fed, last_token=int(self.last_token[slot]), content=content,
            n_pages=len(spill_ids), n_keep=n_keep, nbytes=nbytes)
        req.preemptions += 1
        req.status = PREEMPTED
        req.slot = -1
        self.active[slot] = None
        clear_slot(self.samp, slot)
        self.block_tables[slot] = 0
        self.fed[slot] = 0
        self.last_token[slot] = 0
        self.preemptions += 1
        self.spill_pages += len(spill_ids)
        self.spill_bytes += nbytes
        self._m_preempt.inc()
        self._m_spill_pages.inc(len(spill_ids))
        self._m_spill_bytes.inc(nbytes)
        if self.trace.enabled:
            self.trace.end(req.rid + 1,
                           "decode" if req.tokens else "prefill")
            self.trace.begin(req.rid + 1, "preempted",
                             args={"spill_pages": len(spill_ids),
                                   "fed": fed})
        with self._queue_lock:
            self.sched.requeue(req)
        return req

    # ----------------------------------------------------------------- tick
    def step(self) -> Dict[str, object]:
        """One engine tick: admit, run the slot-masked ragged step, advance
        slots by their consumed chunk lengths.

        Returns {"finished": [Request], "generated": int, "active": int}.
        Exactly ``step_end(step_begin())`` — the split form is the
        double-buffering seam async drivers use (host free between the two
        halves while the device computes).
        """
        return self.step_end(self.step_begin())

    def step_begin(self) -> _PendingStep:
        """First half of a tick: admission (+ preemption policy), chunk
        sizing, ragged input build, and the ASYNC dispatch of the jitted
        step. Returns the in-flight handle `step_end` consumes; raises if a
        step is already in flight. Between `step_begin` and `step_end` the
        host thread is free — the async frontend parks the engine thread
        there so its event loop serves HTTP/SSE/submissions under the
        device compute of tick t (work for tick t+1 lands in the queue
        before t's `step_end` runs its same-tick re-admit)."""
        if self._pending is not None:
            raise RuntimeError("step already in flight (step_end not called)")
        t0 = time.perf_counter()
        paged = self.cache_cfg.paged
        C = self.step_chunk              # token-buffer width fed to the step
        PC = self.chunk                  # prefill growth cap per slot
        tracing = self.trace.enabled
        with use_mesh(self.mesh):
            # 1) admit queued requests into free slots (see _admit)
            if tracing:
                self.trace.begin(0, "tick", args={"tick": self.tick})
                self.trace.begin(0, "admit")
            self._admit()
            if tracing:
                self.trace.end(0, "admit")

            if self.active_count == 0:
                # idle ticks still advance the engine clock — open-loop
                # drivers gate future arrivals on eng.tick
                self.tick += 1
                self._m_idle.inc()
                if tracing:
                    self.trace.end(0, "tick", args={"idle": True})
                with self._tick_cv:
                    self._tick_cv.notify_all()
                return _PendingStep(
                    outs=None, nvalid=None, ndraft=None, t0=t0, fed=0,
                    tracing=tracing, idle=True,
                    result={"finished": [], "generated": 0, "active": 0})
            self._m_active.set(self.active_count)

            # 2) size each slot's chunk under the global token budget:
            #    every active slot gets 1 guaranteed token; prefilling slots
            #    grow toward the prefill chunk (never past the prompt end),
            #    pure-decode slots append up to speculate_k DRAFT tokens —
            #    both only from the leftover budget
            nvalid = np.zeros(self.slots, np.int32)
            ndraft = np.zeros(self.slots, np.int32)
            proposals: Dict[int, np.ndarray] = {}
            leftover = self.token_budget - self.active_count
            for s, req in enumerate(self.active):
                if req is None:
                    continue
                n = 1
                rem = req.n_prefix + req.prompt_len - int(self.fed[s])
                if PC > 1 and rem > 1:     # still prefilling past this tick
                    extra = min(min(PC, rem) - 1, leftover)
                    n += max(0, extra)
                    leftover -= n - 1
                elif self.speculate_k and rem <= 0:
                    # decode round: drafts past the length cap could write
                    # beyond the slot's reserved kv_need positions, so the
                    # cap also bounds the draft count
                    k_cap = min(self.speculate_k,
                                req.max_tokens - 1 - req.n_generated,
                                leftover)
                    if k_cap > 0:
                        hist = np.concatenate(
                            [req.prompt, np.asarray(req.tokens, np.int32)])
                        d = np.asarray(self.drafter.propose(hist, int(k_cap)),
                                       np.int32).reshape(-1)[:k_cap]
                        if d.size:
                            self.drafter.record_proposal(int(d.size))
                            proposals[s] = d
                            ndraft[s] = d.size
                            n += int(d.size)
                            leftover -= int(d.size)
                nvalid[s] = n

            # 3) build this tick's ragged inputs: [B, C] token block per
            #    slot, per-slot start position + valid length
            token = np.zeros((self.slots, C), np.int32)
            pos = np.full(self.slots, -1, np.int32)    # idle: write-suppressed
            use_prefix = self.cfg.num_prefix_embeds > 0
            embeds = (np.zeros((self.slots, C, self.cfg.d_model), np.float32)
                      if use_prefix else None)
            emask = np.zeros((self.slots, C), bool) if use_prefix else None
            for s, req in enumerate(self.active):
                if req is None:
                    continue
                i = int(self.fed[s])
                # shared (read-only) pages cover exactly [0, cached_len):
                # this tick's inserts start at i, so they only ever land in
                # the request's private pages
                assert i >= req.cached_len, (
                    f"slot {s}: insert at {i} would write a shared page "
                    f"(cached prefix {req.cached_len})")
                if req.first_step_tick < 0:
                    req.first_step_tick = self.tick
                pos[s] = i
                for j in range(int(nvalid[s])):
                    idx = i + j
                    if idx < req.n_prefix:
                        embeds[s, j] = req.prefix_embeds[idx]
                        emask[s, j] = True
                    elif idx < req.n_prefix + req.prompt_len:
                        token[s, j] = req.prompt[idx - req.n_prefix]
                    elif j == 0 or s not in proposals:
                        token[s, j] = self.last_token[s]
                    else:                  # chunk tail: this round's drafts
                        token[s, j] = proposals[s][j - 1]

            # 4) ONE jitted step for every slot (ragged when C > 1); the
            #    per-slot sampling rows ride along as one pytree arg and
            #    the step hands back the sampled token + in-step done flag
            if C > 1:
                args = (self.params, jnp.asarray(token), jnp.asarray(pos),
                        jnp.asarray(nvalid))
                if self.speculate_k:
                    args += (jnp.asarray(ndraft),)
                args += (self.cache,)
            else:
                args = (self.params, jnp.asarray(token[:, 0]),
                        jnp.asarray(pos), self.cache)
            if paged:
                args += (jnp.asarray(self.block_tables),)
            if use_prefix:
                if C > 1:
                    args += (jnp.asarray(embeds), jnp.asarray(emask))
                else:
                    args += (jnp.asarray(embeds[:, 0]),
                             jnp.asarray(emask[:, 0]))
            args += ({k: jnp.asarray(v) for k, v in self.samp.items()},)
            fed = int(nvalid.sum())
            self._m_steps.inc()
            self._m_fed.inc(fed)
            for s in range(self.slots):
                if self.active[s] is not None:
                    self._m_chunk.observe(int(nvalid[s]))
            self._profile_tick_start()
            if tracing:
                self.trace.begin(0, "device_step",
                                 args={"tokens_fed": fed,
                                       "active": self.active_count})
            outs = self._step(*args)     # async dispatch: the device is
            #                              now computing; nothing below in
            #                              step_end blocks until np.asarray
        p = _PendingStep(outs=outs, nvalid=nvalid, ndraft=ndraft,
                         t0=t0, fed=fed, tracing=tracing)
        self._pending = p
        return p

    def step_end(self, pending: Optional[_PendingStep] = None) -> Dict[str, object]:
        """Second half of a tick: block on the in-flight device step,
        advance slot state by consumed chunk lengths, publish pages,
        finish / roll back speculation / same-tick re-admit. Accepts the
        handle from `step_begin` (or uses the stored one)."""
        p = self._pending if pending is None else pending
        if p is None:
            raise RuntimeError("no step in flight (call step_begin first)")
        self._pending = None
        if p.idle:
            return p.result
        t0, tracing, fed = p.t0, p.tracing, p.fed
        nvalid, ndraft, outs = p.nvalid, p.ndraft, p.outs
        paged = self.cache_cfg.paged
        with use_mesh(self.mesh):
            if tracing:
                # time the device work to completion — dispatch is
                # serialized under tracing, so trace runs are for
                # inspection, never benchmark rows
                jax.block_until_ready(outs)
                self.trace.end(0, "device_step")
            self._profile_tick_end()
            if self.speculate_k:
                out_tok, n_emit, acc, done, self.cache = outs
                out_tok = np.asarray(out_tok)
                n_emit = np.asarray(n_emit)
                acc = np.asarray(acc)
            else:
                next_tok, done, self.cache = outs
                next_tok = np.asarray(next_tok)
            done = np.asarray(done)

            # 5) advance slot state by consumed chunk lengths; collect
            #    sampled tokens; free finished
            finished, generated = [], 0
            tick_reads = 0                   # roofline attribution (obs.cost)
            tick_ach_bytes = 0.0
            for s, req in enumerate(self.active):
                if req is None:
                    continue
                i = int(self.fed[s])
                n = int(nvalid[s])
                self.fed[s] = i + n
                if self.cost_model is not None:
                    # causal floor: fed token j attends positions [0, i+j]
                    # plus its own insert; achieved: what the configured
                    # cache impl actually moves (StepCostModel branches —
                    # dense capacity for contiguous, full block-table row
                    # + f32 dequant round-trip for the paged ref gather,
                    # causal whole pages and NO dequant for the fused
                    # template, which restores packed planes in VREGs)
                    reads = n * i + n * (n + 1) // 2
                    cm = self.cost_model
                    ach_bytes = cm.achieved_kv_bytes(
                        i, n, cache_kind=self.cache_cfg.kind,
                        impl=self.cache_cfg.impl, capacity=self.capacity,
                        page_size=self.cache_cfg.page_size,
                        max_pages=self.cache_cfg.max_pages_per_seq,
                        bytes_per_token=self._kv_bpt)
                    req.kv_floor_bytes += \
                        (n + reads) * cm.kv_bytes_per_token
                    req.kv_achieved_bytes += ach_bytes
                    tick_reads += reads
                    tick_ach_bytes += ach_bytes
                if paged and req.page_hashes:
                    # publish full PROMPT pages as prefill crosses their
                    # boundaries: content-addressed, so an identical prefix
                    # admitted later references the same physical page.
                    # Pages holding generated tokens are never published.
                    filled = min(int(self.fed[s]), req.prompt_len)
                    while (req.published + 1) * self.cache_cfg.page_size <= filled:
                        j = req.published
                        self.alloc.publish(req.rid, req.page_hashes[j],
                                           req.pages[j])
                        req.published = j + 1
                if i + n - 1 >= req.n_prefix + req.prompt_len - 1:
                    # this chunk consumed the last prompt token or a generated
                    # token -> the last valid position's draw is the next
                    # generated token (speculative rounds emit the accepted
                    # draft prefix + the bonus/corrective draw in one go)
                    k_s = int(ndraft[s])
                    if self.speculate_k:
                        a = int(acc[s])
                        emitted = [int(t) for t in out_tok[s, :int(n_emit[s])]]
                        if k_s:
                            self._m_spec_prop.inc(k_s)
                            self._m_spec_acc.inc(a)
                            req.drafted += k_s
                            req.accepted_drafts += a
                    else:
                        emitted = [int(next_tok[s])]
                    was_first = not req.tokens
                    req.tokens.extend(emitted)
                    tok = emitted[-1]
                    self.last_token[s] = tok
                    self.samp["ngen"][s] = len(req.tokens)
                    generated += len(emitted)
                    self._m_emit.inc()
                    if was_first:
                        req.first_token_tick = self.tick
                        req.status = DECODE
                        if tracing:
                            self.trace.end(req.rid + 1, "prefill")
                            self.trace.begin(req.rid + 1, "decode")
                    if bool(done[s]):
                        # in-step termination: stop-token hit or length cap
                        req.finish_tick = self.tick
                        req.status = FINISHED
                        req.finish_reason = (
                            "stop" if tok in req.sampling.stop_token_ids
                            else "length")
                        self.finished.append(req)
                        finished.append(req)
                        self.active[s] = None
                        clear_slot(self.samp, s)
                        if paged:
                            self.alloc.free(req.rid)
                            self.block_tables[s] = 0
                        (self._m_fin_stop if req.finish_reason == "stop"
                         else self._m_fin_len).inc()
                        self._m_ttft.observe(req.ttft_ticks)
                        self._m_lat.observe(req.latency_ticks)
                        self._m_glen.observe(req.n_generated)
                        if tracing:
                            self.trace.end(req.rid + 1, "decode")
                            self.trace.instant(
                                req.rid + 1, "finished",
                                args={"reason": req.finish_reason,
                                      "tokens": req.n_generated})
                            self.trace.end(req.rid + 1, "request")
                    elif k_s:
                        # ROLLBACK: the step already zero-scattered the
                        # rejected draft entries (positions i+1+a .. i+k_s)
                        # out of every cache leaf; rewind the feed position
                        # so the next round re-inserts there. Speculation
                        # starts strictly after the prompt, so the rewind
                        # target can never reach a shared prefix page.
                        new_fed = i + 1 + a
                        assert new_fed >= req.n_prefix + req.prompt_len \
                            and new_fed > req.cached_len - 1, (
                            f"slot {s}: speculative rewind to {new_fed} "
                            f"would cross the shared/prompt boundary "
                            f"(cached {req.cached_len}, prompt end "
                            f"{req.n_prefix + req.prompt_len})")
                        self.fed[s] = new_fed
            if self.cost_model is not None:
                cm = self.cost_model
                self._m_floor_b.inc(cm.tick_floor_bytes(fed, tick_reads))
                self._m_floor_f.inc(cm.tick_floor_flops(fed, tick_reads))
                self._m_kv_floor.inc(
                    (fed + tick_reads) * cm.kv_bytes_per_token)
                self._m_kv_ach.inc(tick_ach_bytes)
            # freed capacity becomes admission headroom the SAME tick: a
            # stop-token hit admits the queue head before the tick closes
            # (its first chunk runs next tick)
            if finished:
                if tracing:
                    self.trace.begin(0, "admit")
                self._admit()
                if tracing:
                    self.trace.end(0, "admit")
        self.tick += 1
        self._m_tick_s.observe(time.perf_counter() - t0)
        self._m_tick_tok.observe(generated)
        if tracing:
            self.trace.counter("engine", {"active": self.active_count,
                                          "queue": self.sched.queue_depth})
            self.trace.end(0, "tick", args={"generated": generated})
        with self._tick_cv:
            self._tick_cv.notify_all()
        return {"finished": finished, "generated": generated,
                "active": self.active_count}

    def wait_tick(self, tick: int, timeout: float = 0.5) -> None:
        """Block until the engine clock passes `tick` (RequestHandle
        waiters use this when an external driver owns `step()`); the
        timeout bounds the wait in case that driver stops mid-flight."""
        with self._tick_cv:
            self._tick_cv.wait_for(
                lambda: self.tick > tick or not self.driver_active,
                timeout=timeout)

    # ------------------------------------------------------------------ run
    def run(self, max_ticks: int = 1_000_000) -> Dict[str, float]:
        """Drive up to `max_ticks` further ticks, stopping early once queue +
        slots drain. Returns aggregate stats; per-request results live on
        the Request objects."""
        for _ in range(max_ticks):
            if not self.has_work:
                break
            self.step()
        return self.stats()

    def reset_metrics(self) -> None:
        """Drop accumulated timing/counter state (e.g. after a jit warmup)
        without touching in-flight requests or the cache. Registry
        registrations (and callback gauges) survive — only values zero."""
        self.finished = []
        self.preemptions = self.resumes = 0
        self.spill_pages = self.spill_bytes = 0
        self.metrics.reset()
        if self.alloc is not None:
            self.alloc.reset_stats()

    # --------------------------------------------------------- obs plumbing
    @property
    def _emit_rounds(self) -> int:
        """Slot-rounds that emitted tokens (registry-backed; the counter
        behind stats()['tokens_per_step'])."""
        return int(self._m_emit.value)

    def _profile_tick_start(self) -> None:
        """Start the optional jax.profiler capture on the first served
        tick (`ObsConfig.jax_profile_ticks`); disabled on any failure."""
        if self._prof_ticks_left <= 0 or self._prof_active:
            return
        try:
            jax.profiler.start_trace(self.obs.jax_profile_dir)
            self._prof_active = True
        except Exception:              # platform without profiler support
            self._prof_ticks_left = 0

    def _profile_tick_end(self) -> None:
        if not self._prof_active:
            return
        self._prof_ticks_left -= 1
        if self._prof_ticks_left <= 0:
            jax.profiler.stop_trace()
            self._prof_active = False

    # ----------------------------------------------------------- accounting
    def kv_bytes_per_token(self) -> int:
        """PER-DEVICE cache bytes one token occupies across all layers, in
        the active cache mode (bf16 slot/page storage, or AMS packed
        planes). On a head-sharded tp>1 mesh each device holds kv/tp heads
        of every page, so this scales as 1/tp — the residency/bandwidth
        number the paper's wins are about. tp=1: the full-pool bytes,
        unchanged."""
        from repro.cache.pool import pool_bytes_per_token
        dims = model_dims(self.cfg, self.mesh.shape["model"])
        return self.cfg.num_layers * pool_bytes_per_token(
            dims.kv // self._kv_shards, dims.hd, self.cache_cfg)

    def kv_compression_vs_bf16(self) -> float:
        """bf16-cache bytes / active-mode bytes per token (1.0 for bf16)."""
        dims = model_dims(self.cfg, self.mesh.shape["model"])
        return compression_vs_bf16(dims.kv, dims.hd, self.cache_cfg)

    def stats(self) -> Dict[str, float]:
        """Aggregate serving stats, computed FROM the metrics registry
        (`repro.obs.metrics`) — the tick histograms keep raw observations
        in insertion order, so every percentile below is bit-identical to
        the pre-registry hand-counter implementation (pinned by
        tests/test_obs.py). With ``ObsConfig(enabled=False)`` the
        accumulated telemetry reads as zero; pure-state values (kv bytes
        per token, queue depth) stay real."""
        raw_s = self._m_tick_s.raw_values()
        raw_t = self._m_tick_tok.raw_values()
        tick_s = np.asarray(raw_s) if raw_s else np.zeros(1)
        tok = np.asarray(raw_t) if raw_t else np.zeros(1)
        total_s = float(tick_s.sum())
        decode_ticks = tick_s[tok > 0]
        # TTFT (submit -> first token) and end-to-end request latency, in
        # engine ticks over finished requests — TTFT is the number chunked
        # prefill moves (ceil(prompt/C) prefill ticks instead of prompt_len)
        # requests end at VARIABLE lengths (stop tokens): both arrays are
        # per-request actuals, so early exits shorten the percentiles
        ttft = np.asarray(self._m_ttft.raw_values(), np.float64)
        e2e = np.asarray(self._m_lat.raw_values(), np.float64)
        glen = np.asarray(self._m_glen.raw_values(), np.float64)
        spec_prop = int(self._m_spec_prop.value)
        spec_acc = int(self._m_spec_acc.value)
        emit_rounds = int(self._m_emit.value)

        def pct(a, q):
            return float(np.percentile(a, q)) if a.size else 0.0

        out = {
            "ticks": len(raw_s),
            "requests_finished": int(self._m_finished.total),
            "tokens_generated": int(tok.sum()),
            "tokens_per_s": float(tok.sum() / total_s) if total_s else 0.0,
            "decode_ms_median": (1e3 * float(np.median(decode_ticks))
                                 if decode_ticks.size else 0.0),
            "decode_ms_p99": (1e3 * float(np.percentile(decode_ticks, 99))
                              if decode_ticks.size else 0.0),
            "ttft_ticks_mean": float(ttft.mean()) if ttft.size else 0.0,
            "ttft_ticks_p50": pct(ttft, 50),
            "ttft_ticks_p99": pct(ttft, 99),
            "latency_ticks_mean": float(e2e.mean()) if e2e.size else 0.0,
            "latency_ticks_p50": pct(e2e, 50),
            "latency_ticks_p99": pct(e2e, 99),
            "gen_tokens_mean": float(glen.mean()) if glen.size else 0.0,
            "stopped_early": int(self._m_fin_stop.value),
            "queue_depth": self.sched.queue_depth,
            "kv_bytes_per_token": self.kv_bytes_per_token(),
            "kv_compression_vs_bf16": self.kv_compression_vs_bf16(),
            # speculative decoding: drafts scored / accepted, and tokens
            # emitted per emitting slot-round (1.0 when not speculating —
            # every emission is a single draw)
            "spec_proposed": spec_prop,
            "spec_accepted": spec_acc,
            "accept_rate": spec_acc / spec_prop if spec_prop else 0.0,
            "tokens_per_step": (float(tok.sum()) / emit_rounds
                                if emit_rounds else 0.0),
            # preemption / host-spill tier (plain ints: real even with
            # ObsConfig(enabled=False), like the allocator's hit counters)
            "preemptions": self.preemptions,
            "resumes": self.resumes,
            "spill_pages": self.spill_pages,
            "spill_bytes": self.spill_bytes,
        }
        if self.alloc is not None:
            out["free_pages"] = self.alloc.free_pages
            out.update(self.alloc.stats())
            prompt_toks = self._m_prompt.value
            out["cached_token_frac"] = (
                self._m_cached.value / prompt_toks if prompt_toks else 0.0)
        if self.cost_model is not None:
            # roofline attribution (obs.cost; full report: obs.attribution)
            cm = self.cost_model
            measured = float(out["kv_bytes_per_token"])
            kv_floor = self._m_kv_floor.value
            kv_ach = self._m_kv_ach.value
            out["kv_bytes_per_token_floor"] = cm.kv_bytes_per_token
            out["kv_bytes_per_token_ideal"] = cm.kv_ideal_bytes_per_token
            out["kv_floor_ratio"] = measured / cm.kv_bytes_per_token
            out["kv_vs_ideal_floor"] = measured / cm.kv_ideal_bytes_per_token
            out["kv_achieved_vs_floor"] = (kv_ach / kv_floor
                                           if kv_floor else 0.0)
            out["floor_hbm_bytes"] = self._m_floor_b.value
            out["floor_flops"] = self._m_floor_f.value
        return out
