"""Continuous-batching serving engine over the AMS-quantized model.

This is the paper's deployment story made a serving hot path instead of a
fixed-batch benchmark loop: weights are AMS-quantized/packed ahead of time
(`QuantPolicy` -> `quantize_params`), and ONE jitted slot-masked decode step
(`launch.steps.build_engine_step`) then serves every in-flight request per
tick, streaming the packed planes through `apply_linear`'s ``ref`` /
``fused_ref`` / ``pallas_interpret`` impls.

Architecture (Orca-style iteration-level scheduling):

  * the KV cache is either a fixed [slots, capacity] tensor (contiguous
    mode) or a POOL of fixed-size pages addressed through per-request
    block tables (`repro.cache`, paged-bf16 / paged-AMS modes — the AMS
    pool stores each K/V vector in the paper's packed e2m2 planes,
    quantized once at insert). Each slot holds one request with its own
    position counter (`decode_step` takes [B] per-slot positions;
    negative = idle slot, cache write suppressed);
  * a FIFO scheduler (`launch.scheduler`) admits queued requests into freed
    slots; admission is capacity-checked at submit time (contiguous) or
    gated on the free-PAGE budget at admit time (paged — short requests
    reserve only their own pages, not worst-case slots), so nothing is
    ever preempted mid-flight;
  * completed PROMPT pages are PREFIX-CACHED across requests (paged modes,
    on by default; ``CacheConfig(prefix_cache=False)`` disables): each full
    prompt page is content-addressed by a prefix-chain hash, and a request
    whose prompt shares a cached page-aligned prefix references the SAME
    physical pages (refcounted, read-only) and starts prefill at the cached
    length — a shared 1k-token system prompt prefills once, not once per
    request. Admission charges only the uncached page count; refcount-0
    cached pages stay resident in an LRU until memory pressure evicts them.
    Reuse is bit-exact because the pool's insert quantization is
    deterministic per (token, head);
  * prefill is CHUNKED INTO THE DECODE BATCH as a RAGGED MULTI-TOKEN STEP:
    each tick, every active slot contributes a variable-length block of up
    to ``prefill_chunk`` tokens — prefilling slots consume a prompt chunk
    (and any modality prefix embeddings), decoding slots consume 1 — all
    executed as ONE jitted program (`launch.steps.build_engine_step` with
    ``chunk=C``). Logits are taken in-step at each slot's last valid
    token, so time-to-first-token scales with ceil(prompt/C) ticks instead
    of prompt length. A global per-tick TOKEN BUDGET caps the chunk total;
    every active slot is guaranteed one token per tick and admission is
    budget-aware (`FIFOScheduler.admit(max_admit=...)`), so a long prefill
    can never starve decode slots. One program, no separate prefill
    compilation, no batch-shape churn. (``prefill_chunk=1`` — the default,
    and the only mode for recurrent-state families — degenerates to the
    original one-position-per-tick step.);
  * sampling is ON-DEVICE and PER-REQUEST (`repro.launch.sampling`): each
    request carries a `SamplingParams(temperature, top_k, top_p, seed,
    max_tokens, stop_token_ids)`; the step applies the logit transforms
    and categorical draw from per-slot folded PRNG keys and decides
    termination (stop-token hit or length cap) in-step, so only [B] int32
    tokens + [B] done bools cross to the host per tick. ``temperature=0``
    (the default) lowers to the exact argmax path, keeping every greedy
    stream-equivalence guarantee bit-identical. Seeded streams replay
    bit-identically across engine restarts and slot reassignment: the
    draw key folds in the REQUEST id and the request's own token index,
    never the slot or tick. A finished slot frees its pages (prefix pages
    stay published per the refcount semantics above) and the queue is
    re-polled the SAME tick, so early EOS turns directly into admission
    headroom;
  * SPECULATIVE DECODING rides the same ragged step
    (`launch.speculative`, ``speculate_k=k`` + ``drafter``): on
    pure-decode rounds a cheap host drafter proposes up to k tokens per
    slot, the step feeds ``[last_token, d_1..d_k]`` so ONE pass scores
    every draft, and an on-device verify epilogue accepts the longest
    correct prefix, draws the bonus/corrective token, terminates in-step,
    and zero-scatters rejected KV entries back to pool-initial state —
    the engine then rewinds its feed position (never past the prompt, so
    shared prefix pages are structurally untouchable). Greedy streams
    stay bit-identical to non-speculative decoding; a round emits 1..k+1
    tokens per model pass (``stats()``: ``accept_rate`` /
    ``tokens_per_step``). See docs/speculative.md.

Because every slot's computation is row-independent (attention hard-masks
invalid cache positions to exact zeros), a request's token stream is
identical whether it runs alone or packed against arbitrary neighbours —
``tests/test_engine.py`` pins this batch-invariance against the one-shot
``launch.serve.generate`` path. (MoE configs are the exception: capacity-
based expert routing couples tokens across the batch.)

Quickstart::

    eng = ServeEngine("qwen2-7b", reduced=True, scheme="fp5.33-e2m3",
                      slots=4, capacity=64)
    req = eng.submit(np.array([1, 2, 3]), max_tokens=16)
    eng.run()
    print(req.tokens)
"""

from __future__ import annotations

import itertools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import (
    CacheConfig,
    PageAllocator,
    compression_vs_bf16,
    prefix_page_hashes,
)
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.core.policy import QuantPolicy
from repro.launch.mesh import make_driver_mesh, use_mesh
from repro.launch.sampling import (
    GREEDY,
    SamplingParams,
    clear_slot,
    fill_slot,
    request_key,
    slot_batch,
)
from repro.launch.scheduler import FIFOScheduler, Request
from repro.launch.steps import build_engine_step
from repro.models import init_params, make_cache, model_dims, reset_cache_slot
from repro.models.common import quantize_params


class ServeEngine:
    """Slot-based continuous-batching engine (see module docstring)."""

    def __init__(self, arch: str, *, reduced: bool = True,
                 scheme: str = "fp5.33-e2m3", strategy: str = "set_lsb",
                 impl: str = "ref", mesh_kind: str = "none",
                 slots: int = 4, capacity: int = 128, max_queue: Optional[int] = None,
                 cache_config: Optional[CacheConfig] = None,
                 prefill_chunk: int = 1, token_budget: Optional[int] = None,
                 speculate_k: int = 0, drafter="ngram",
                 seed: int = 0, params=None, verbose: bool = False):
        cfg = get_config(arch)
        if reduced:
            cfg = cfg.reduced()
        self.cfg = cfg
        self.scheme = scheme
        self.slots = slots
        self.capacity = capacity
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.chunk = prefill_chunk   # chunk support is gated by
        #                              build_engine_step(check_chunked_support)
        if speculate_k < 0:
            raise ValueError("speculate_k must be >= 0")
        self.speculate_k = speculate_k
        # the jitted step's chunk width must hold 1 fed token + k drafts
        # per slot; prefill growth stays capped at prefill_chunk
        self.step_chunk = (max(self.chunk, speculate_k + 1) if speculate_k
                           else self.chunk)
        # per-tick token budget: every active slot is guaranteed 1; prefill
        # chunks and draft blocks grow only into the leftover. Default = no
        # throttling.
        self.token_budget = (token_budget if token_budget is not None
                             else slots * self.step_chunk)
        if self.token_budget < 1:
            raise ValueError("token_budget must be >= 1")
        ccfg = cache_config or CacheConfig()
        if ccfg.paged:
            ccfg = ccfg.sized(capacity=capacity, slots=slots)
        self.cache_cfg = ccfg
        quant = None
        if scheme != "fp16":
            quant = QuantPolicy(scheme=scheme, strategy=strategy, impl=impl,
                                min_elements=1 << 10)
        self.rcfg = RunConfig(model=cfg, seq_len=capacity, global_batch=slots,
                              mode="decode", quant=quant)
        self.mesh = make_driver_mesh(mesh_kind)

        with use_mesh(self.mesh):
            tp = self.mesh.shape["model"]
            if params is None:
                params = init_params(jax.random.PRNGKey(seed), cfg, tp=tp)
            params = jax.tree.map(
                lambda x: x.astype(jnp.bfloat16) if x.ndim >= 2 else x, params)
            if quant is not None:
                t0 = time.time()
                params = quantize_params(params, quant)
                if verbose:
                    print(f"[ptq] quantized to {scheme} ({strategy}) "
                          f"in {time.time()-t0:.1f}s", flush=True)
            self.params = params
            self.cache = make_cache(cfg, slots, capacity, tp=tp,
                                    dtype=jnp.bfloat16,
                                    cache_cfg=ccfg if ccfg.paged else None)
            self._step, _, _ = build_engine_step(
                self.mesh, cfg, self.rcfg,
                cache_cfg=ccfg if ccfg.paged else None,
                chunk=self.step_chunk, sampling=True,
                speculate_k=self.speculate_k)
            # the drafter proposes from the (possibly quantized) serving
            # params — resolved here so "self" binds the engine's own stack
            self.drafter = None
            if self.speculate_k:
                from repro.launch.speculative import Drafter, make_drafter
                if isinstance(drafter, str):
                    drafter = make_drafter(drafter, params=params, cfg=cfg,
                                           capacity=capacity, tp=tp,
                                           policy=quant)
                if not isinstance(drafter, Drafter):
                    raise TypeError(f"drafter must be a Drafter or name, "
                                    f"got {type(drafter).__name__}")
                self.drafter = drafter
            # paged pools need no per-slot reset: positions are written
            # front-to-front per request, so every valid key is fresh, and
            # recurrent-state families are rejected by check_paged_support
            self._reset = (None if ccfg.paged else
                           jax.jit(reset_cache_slot, donate_argnums=(0,)))

        # host-side slot state
        if ccfg.paged:
            self.alloc: Optional[PageAllocator] = PageAllocator(
                ccfg.num_pages, ccfg.page_size)
            self.block_tables = np.zeros(
                (slots, ccfg.max_pages_per_seq), np.int32)
            # a request can never outgrow its block-table row or the pool
            eff_cap = min(ccfg.max_pages_per_seq, ccfg.num_pages) * ccfg.page_size
        else:
            self.alloc = None
            self.block_tables = None
            eff_cap = capacity
        self.sched = FIFOScheduler(eff_cap, max_queue=max_queue)
        self.active: List[Optional[Request]] = [None] * slots
        self.fed = np.zeros(slots, np.int32)   # inputs consumed == insert pos
        self.last_token = np.zeros(slots, np.int32)
        # per-slot sampling state shipped to the step each tick (key, ngen,
        # temperature, top_k, top_p, max_tokens, stop_ids rows)
        self.samp = slot_batch(slots)
        self.tick = 0
        self.finished: List[Request] = []
        self._rid = itertools.count()
        self._tick_s: List[float] = []         # wall seconds per non-idle tick
        self._tick_tokens: List[int] = []      # tokens generated per tick
        self._prompt_tokens = 0                # prompt positions admitted
        self._cached_tokens = 0                # ... served from shared pages
        self._spec_proposed = 0                # draft tokens scored
        self._spec_accepted = 0                # ... accepted by the verify
        self._emit_rounds = 0                  # slot-rounds emitting tokens

    # ------------------------------------------------------------- frontend
    def submit(self, prompt, max_tokens: Optional[int] = None,
               prefix_embeds=None,
               sampling: Optional[SamplingParams] = None) -> Request:
        """Enqueue a request. Raises if it can never fit a cache slot.
        (`Request.__post_init__` normalizes the prompt to [P] int32.)

        ``sampling`` configures the per-request draw (temperature/top_k/
        top_p/seed) and termination (stop_token_ids + max_tokens); omitted
        -> greedy argmax, exactly the PR 1-4 behaviour. ``max_tokens`` is
        the length CAP — ``sampling.max_tokens`` wins when both are given,
        and a stop-token hit ends the stream earlier."""
        sp = sampling if sampling is not None else GREEDY
        if sp.max_tokens is not None:
            max_tokens = sp.max_tokens
        if max_tokens is None:
            raise ValueError(
                "max_tokens required (argument or SamplingParams.max_tokens)")
        if prefix_embeds is not None:
            prefix_embeds = np.asarray(prefix_embeds, np.float32)
            if self.cfg.num_prefix_embeds == 0:
                raise ValueError(
                    f"{self.cfg.name} has no modality frontend; "
                    "prefix_embeds unsupported")
            if (prefix_embeds.ndim != 2
                    or prefix_embeds.shape[1] != self.cfg.d_model):
                raise ValueError(
                    f"prefix_embeds must be [n, d_model={self.cfg.d_model}], "
                    f"got {prefix_embeds.shape}")
        rid = next(self._rid)
        # request-level PRNG key: seed + REQUEST id (never the slot/tick),
        # so seeded streams replay across restarts and slot reassignment
        req = Request(rid=rid, prompt=prompt, max_tokens=max_tokens,
                      prefix_embeds=prefix_embeds, sampling=sp,
                      key_data=request_key(sp.seed, rid))
        ccfg = self.cache_cfg
        if ccfg.paged and ccfg.prefix_cache and prefix_embeds is None:
            # chain hash per FULL prompt page — the prefix-cache identity
            # (modality prefixes are request-local floats, not hashable
            # token pages, so VLM/audio requests skip the cache)
            req.page_hashes = prefix_page_hashes(
                req.prompt, ccfg.page_size, ccfg.content_key)
        return self.sched.submit(req, self.tick)

    @property
    def has_work(self) -> bool:
        return any(r is not None for r in self.active) or len(self.sched) > 0

    @property
    def active_count(self) -> int:
        return sum(r is not None for r in self.active)

    # ------------------------------------------------------------ admission
    def _admit(self) -> int:
        """Admit queued requests into free slots; returns the count placed.

        Contiguous: reset slot caches first — recurrent SSM/RG-LRU states
        integrate garbage while a slot idles; KV entries are position-
        masked but cleared too. Paged: reserve the request's worst-case
        pages and publish its block-table row instead; admission is
        additionally gated on the free-page budget via `fits`. Admission
        is token-budget-aware: active slots never exceed the per-tick
        budget, so every slot advances every tick.

        Called at tick START and AGAIN after slots free at tick end, so an
        early-terminating (stop-token) request's capacity becomes an
        admission the same tick it finishes.
        """
        paged = self.cache_cfg.paged
        free = [s for s, r in enumerate(self.active) if r is None]
        room = self.token_budget - self.active_count
        fits = None
        if paged:
            ps = self.cache_cfg.page_size

            # cache-aware admission: the longest resident prefix of the
            # request's page hashes is SHARED (pinned, read-only) and
            # only the uncached page count charges the free budget.
            # Allocation happens right here, inside the check — admit's
            # contract (fits(head) True => head is admitted) makes the
            # mutation safe, and it keeps the budget exact when one
            # tick both pins cached pages and evicts cold ones.
            def fits(r):
                need = self.alloc.pages_needed(r.kv_need)
                # always re-feed at least the last prompt token (its
                # logits produce the first generated token), so the
                # matchable prefix stops one position short of the end
                hashes = r.page_hashes[
                    : (r.n_prefix + r.prompt_len - 1) // ps]
                if not self.alloc.can_alloc(need, hashes):
                    return False
                r.pages, shared = self.alloc.alloc(r.rid, need, hashes)
                r.cached_len = shared * ps
                r.published = shared
                return True
        placed = self.sched.admit(free, self.tick, fits=fits,
                                  max_admit=max(0, room))
        for slot, req in placed:
            if paged:
                self.block_tables[slot] = self.alloc.block_table_row(
                    req.rid, self.block_tables.shape[1])
                self._prompt_tokens += req.n_prefix + req.prompt_len
                self._cached_tokens += req.cached_len
            else:
                self.cache = self._reset(self.cache, slot)
            self.active[slot] = req
            # prefill skip: cached pages already hold positions
            # [0, cached_len), so this slot starts feeding there
            self.fed[slot] = req.cached_len
            fill_slot(self.samp, slot, req.sampling, req.key_data,
                      req.max_tokens)
        return len(placed)

    # ----------------------------------------------------------------- tick
    def step(self) -> Dict[str, object]:
        """One engine tick: admit, run the slot-masked ragged step, advance
        slots by their consumed chunk lengths.

        Returns {"finished": [Request], "generated": int, "active": int}.
        """
        t0 = time.perf_counter()
        paged = self.cache_cfg.paged
        C = self.step_chunk              # token-buffer width fed to the step
        PC = self.chunk                  # prefill growth cap per slot
        with use_mesh(self.mesh):
            # 1) admit queued requests into free slots (see _admit)
            self._admit()

            if self.active_count == 0:
                # idle ticks still advance the engine clock — open-loop
                # drivers gate future arrivals on eng.tick
                self.tick += 1
                return {"finished": [], "generated": 0, "active": 0}

            # 2) size each slot's chunk under the global token budget:
            #    every active slot gets 1 guaranteed token; prefilling slots
            #    grow toward the prefill chunk (never past the prompt end),
            #    pure-decode slots append up to speculate_k DRAFT tokens —
            #    both only from the leftover budget
            nvalid = np.zeros(self.slots, np.int32)
            ndraft = np.zeros(self.slots, np.int32)
            proposals: Dict[int, np.ndarray] = {}
            leftover = self.token_budget - self.active_count
            for s, req in enumerate(self.active):
                if req is None:
                    continue
                n = 1
                rem = req.n_prefix + req.prompt_len - int(self.fed[s])
                if PC > 1 and rem > 1:     # still prefilling past this tick
                    extra = min(min(PC, rem) - 1, leftover)
                    n += max(0, extra)
                    leftover -= n - 1
                elif self.speculate_k and rem <= 0:
                    # decode round: drafts past the length cap could write
                    # beyond the slot's reserved kv_need positions, so the
                    # cap also bounds the draft count
                    k_cap = min(self.speculate_k,
                                req.max_tokens - 1 - req.n_generated,
                                leftover)
                    if k_cap > 0:
                        hist = np.concatenate(
                            [req.prompt, np.asarray(req.tokens, np.int32)])
                        d = np.asarray(self.drafter.propose(hist, int(k_cap)),
                                       np.int32).reshape(-1)[:k_cap]
                        if d.size:
                            proposals[s] = d
                            ndraft[s] = d.size
                            n += int(d.size)
                            leftover -= int(d.size)
                nvalid[s] = n

            # 3) build this tick's ragged inputs: [B, C] token block per
            #    slot, per-slot start position + valid length
            token = np.zeros((self.slots, C), np.int32)
            pos = np.full(self.slots, -1, np.int32)    # idle: write-suppressed
            use_prefix = self.cfg.num_prefix_embeds > 0
            embeds = (np.zeros((self.slots, C, self.cfg.d_model), np.float32)
                      if use_prefix else None)
            emask = np.zeros((self.slots, C), bool) if use_prefix else None
            for s, req in enumerate(self.active):
                if req is None:
                    continue
                i = int(self.fed[s])
                # shared (read-only) pages cover exactly [0, cached_len):
                # this tick's inserts start at i, so they only ever land in
                # the request's private pages
                assert i >= req.cached_len, (
                    f"slot {s}: insert at {i} would write a shared page "
                    f"(cached prefix {req.cached_len})")
                if req.first_step_tick < 0:
                    req.first_step_tick = self.tick
                pos[s] = i
                for j in range(int(nvalid[s])):
                    idx = i + j
                    if idx < req.n_prefix:
                        embeds[s, j] = req.prefix_embeds[idx]
                        emask[s, j] = True
                    elif idx < req.n_prefix + req.prompt_len:
                        token[s, j] = req.prompt[idx - req.n_prefix]
                    elif j == 0 or s not in proposals:
                        token[s, j] = self.last_token[s]
                    else:                  # chunk tail: this round's drafts
                        token[s, j] = proposals[s][j - 1]

            # 4) ONE jitted step for every slot (ragged when C > 1); the
            #    per-slot sampling rows ride along as one pytree arg and
            #    the step hands back the sampled token + in-step done flag
            if C > 1:
                args = (self.params, jnp.asarray(token), jnp.asarray(pos),
                        jnp.asarray(nvalid))
                if self.speculate_k:
                    args += (jnp.asarray(ndraft),)
                args += (self.cache,)
            else:
                args = (self.params, jnp.asarray(token[:, 0]),
                        jnp.asarray(pos), self.cache)
            if paged:
                args += (jnp.asarray(self.block_tables),)
            if use_prefix:
                if C > 1:
                    args += (jnp.asarray(embeds), jnp.asarray(emask))
                else:
                    args += (jnp.asarray(embeds[:, 0]),
                             jnp.asarray(emask[:, 0]))
            args += ({k: jnp.asarray(v) for k, v in self.samp.items()},)
            if self.speculate_k:
                out_tok, n_emit, acc, done, self.cache = self._step(*args)
                out_tok = np.asarray(out_tok)
                n_emit = np.asarray(n_emit)
                acc = np.asarray(acc)
            else:
                next_tok, done, self.cache = self._step(*args)
                next_tok = np.asarray(next_tok)
            done = np.asarray(done)

            # 5) advance slot state by consumed chunk lengths; collect
            #    sampled tokens; free finished
            finished, generated = [], 0
            for s, req in enumerate(self.active):
                if req is None:
                    continue
                i = int(self.fed[s])
                n = int(nvalid[s])
                self.fed[s] = i + n
                if paged and req.page_hashes:
                    # publish full PROMPT pages as prefill crosses their
                    # boundaries: content-addressed, so an identical prefix
                    # admitted later references the same physical page.
                    # Pages holding generated tokens are never published.
                    filled = min(int(self.fed[s]), req.prompt_len)
                    while (req.published + 1) * self.cache_cfg.page_size <= filled:
                        j = req.published
                        self.alloc.publish(req.rid, req.page_hashes[j],
                                           req.pages[j])
                        req.published = j + 1
                if i + n - 1 >= req.n_prefix + req.prompt_len - 1:
                    # this chunk consumed the last prompt token or a generated
                    # token -> the last valid position's draw is the next
                    # generated token (speculative rounds emit the accepted
                    # draft prefix + the bonus/corrective draw in one go)
                    k_s = int(ndraft[s])
                    if self.speculate_k:
                        a = int(acc[s])
                        emitted = [int(t) for t in out_tok[s, :int(n_emit[s])]]
                        if k_s:
                            self._spec_proposed += k_s
                            self._spec_accepted += a
                            req.drafted += k_s
                            req.accepted_drafts += a
                    else:
                        emitted = [int(next_tok[s])]
                    was_first = not req.tokens
                    req.tokens.extend(emitted)
                    tok = emitted[-1]
                    self.last_token[s] = tok
                    self.samp["ngen"][s] = len(req.tokens)
                    generated += len(emitted)
                    self._emit_rounds += 1
                    if was_first:
                        req.first_token_tick = self.tick
                    if bool(done[s]):
                        # in-step termination: stop-token hit or length cap
                        req.finish_tick = self.tick
                        req.finish_reason = (
                            "stop" if tok in req.sampling.stop_token_ids
                            else "length")
                        self.finished.append(req)
                        finished.append(req)
                        self.active[s] = None
                        clear_slot(self.samp, s)
                        if paged:
                            self.alloc.free(req.rid)
                            self.block_tables[s] = 0
                    elif k_s:
                        # ROLLBACK: the step already zero-scattered the
                        # rejected draft entries (positions i+1+a .. i+k_s)
                        # out of every cache leaf; rewind the feed position
                        # so the next round re-inserts there. Speculation
                        # starts strictly after the prompt, so the rewind
                        # target can never reach a shared prefix page.
                        new_fed = i + 1 + a
                        assert new_fed >= req.n_prefix + req.prompt_len \
                            and new_fed > req.cached_len - 1, (
                            f"slot {s}: speculative rewind to {new_fed} "
                            f"would cross the shared/prompt boundary "
                            f"(cached {req.cached_len}, prompt end "
                            f"{req.n_prefix + req.prompt_len})")
                        self.fed[s] = new_fed
            # freed capacity becomes admission headroom the SAME tick: a
            # stop-token hit admits the queue head before the tick closes
            # (its first chunk runs next tick)
            if finished:
                self._admit()
        self.tick += 1
        self._tick_s.append(time.perf_counter() - t0)
        self._tick_tokens.append(generated)
        return {"finished": finished, "generated": generated,
                "active": self.active_count}

    # ------------------------------------------------------------------ run
    def run(self, max_ticks: int = 1_000_000) -> Dict[str, float]:
        """Drive up to `max_ticks` further ticks, stopping early once queue +
        slots drain. Returns aggregate stats; per-request results live on
        the Request objects."""
        for _ in range(max_ticks):
            if not self.has_work:
                break
            self.step()
        return self.stats()

    def reset_metrics(self) -> None:
        """Drop accumulated timing/counter state (e.g. after a jit warmup)
        without touching in-flight requests or the cache."""
        self._tick_s = []
        self._tick_tokens = []
        self.finished = []
        self._prompt_tokens = 0
        self._cached_tokens = 0
        self._spec_proposed = 0
        self._spec_accepted = 0
        self._emit_rounds = 0
        if self.alloc is not None:
            self.alloc.reset_stats()

    # ----------------------------------------------------------- accounting
    def kv_bytes_per_token(self) -> int:
        """Cache bytes one token occupies across all layers, in the active
        cache mode (bf16 slot/page storage, or AMS packed planes)."""
        from repro.cache.pool import pool_bytes_per_token
        dims = model_dims(self.cfg, self.mesh.shape["model"])
        return self.cfg.num_layers * pool_bytes_per_token(
            dims.kv, dims.hd, self.cache_cfg)

    def kv_compression_vs_bf16(self) -> float:
        """bf16-cache bytes / active-mode bytes per token (1.0 for bf16)."""
        dims = model_dims(self.cfg, self.mesh.shape["model"])
        return compression_vs_bf16(dims.kv, dims.hd, self.cache_cfg)

    def stats(self) -> Dict[str, float]:
        tick_s = np.asarray(self._tick_s) if self._tick_s else np.zeros(1)
        tok = np.asarray(self._tick_tokens) if self._tick_tokens else np.zeros(1)
        total_s = float(tick_s.sum())
        decode_ticks = tick_s[tok > 0]
        # TTFT (submit -> first token) and end-to-end request latency, in
        # engine ticks over finished requests — TTFT is the number chunked
        # prefill moves (ceil(prompt/C) prefill ticks instead of prompt_len)
        # requests end at VARIABLE lengths (stop tokens): both arrays are
        # per-request actuals, so early exits shorten the percentiles
        ttft = np.asarray([r.ttft_ticks for r in self.finished
                           if r.first_token_tick >= 0], np.float64)
        e2e = np.asarray([r.latency_ticks for r in self.finished], np.float64)
        glen = np.asarray([r.n_generated for r in self.finished], np.float64)

        def pct(a, q):
            return float(np.percentile(a, q)) if a.size else 0.0

        out = {
            "ticks": len(self._tick_s),
            "requests_finished": len(self.finished),
            "tokens_generated": int(tok.sum()),
            "tokens_per_s": float(tok.sum() / total_s) if total_s else 0.0,
            "decode_ms_median": (1e3 * float(np.median(decode_ticks))
                                 if decode_ticks.size else 0.0),
            "decode_ms_p99": (1e3 * float(np.percentile(decode_ticks, 99))
                              if decode_ticks.size else 0.0),
            "ttft_ticks_mean": float(ttft.mean()) if ttft.size else 0.0,
            "ttft_ticks_p50": pct(ttft, 50),
            "ttft_ticks_p99": pct(ttft, 99),
            "latency_ticks_mean": float(e2e.mean()) if e2e.size else 0.0,
            "latency_ticks_p50": pct(e2e, 50),
            "latency_ticks_p99": pct(e2e, 99),
            "gen_tokens_mean": float(glen.mean()) if glen.size else 0.0,
            "stopped_early": sum(r.finish_reason == "stop"
                                 for r in self.finished),
            "queue_depth": self.sched.queue_depth,
            "kv_bytes_per_token": self.kv_bytes_per_token(),
            "kv_compression_vs_bf16": self.kv_compression_vs_bf16(),
            # speculative decoding: drafts scored / accepted, and tokens
            # emitted per emitting slot-round (1.0 when not speculating —
            # every emission is a single draw)
            "spec_proposed": self._spec_proposed,
            "spec_accepted": self._spec_accepted,
            "accept_rate": (self._spec_accepted / self._spec_proposed
                            if self._spec_proposed else 0.0),
            "tokens_per_step": (float(tok.sum()) / self._emit_rounds
                                if self._emit_rounds else 0.0),
        }
        if self.alloc is not None:
            out["free_pages"] = self.alloc.free_pages
            out.update(self.alloc.stats())
            out["cached_token_frac"] = (
                self._cached_tokens / self._prompt_tokens
                if self._prompt_tokens else 0.0)
        return out
