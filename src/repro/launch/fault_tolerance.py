"""Fault-tolerance & elasticity utilities for the training driver.

Designed for thousands of nodes, demonstrated on one:

  * RunGuard      — retry-with-restore loop: any step exception triggers a
                    restore from the last complete checkpoint and resumption;
                    crash-at-any-point safety comes from the checkpoint
                    manager's manifest-last atomic layout.
  * Straggler     — per-step deadline monitor. On a real pod the hook
                    escalates (alert -> re-shard -> evict); offline we log
                    and count. Deadline auto-calibrates to median step time.
  * FailureInjector — deterministic fault injection for tests/drills
                    (REPRO_INJECT_FAIL_AT=<step>[,<step>...]).
  * elastic re-shard — the data pipeline is stateless/seekable, so changing
                    the DP world size only changes (shard, num_shards) in
                    batch(); params/opt state restore is sharding-agnostic
                    (checkpoints store full arrays). See train.py --dp-size.
"""

from __future__ import annotations

import os
import statistics
import time
from typing import Callable, List, Optional


class FailureInjector:
    def __init__(self, env: str = "REPRO_INJECT_FAIL_AT"):
        spec = os.environ.get(env, "")
        self.steps = {int(s) for s in spec.split(",") if s.strip()}
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.steps and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


class StragglerMonitor:
    """Deadline-based straggler detection with self-calibrating threshold."""

    def __init__(self, factor: float = 3.0, warmup: int = 5,
                 on_straggle: Optional[Callable[[int, float], None]] = None):
        self.factor = factor
        self.warmup = warmup
        self.times: List[float] = []
        self.straggles: List[int] = []
        self.on_straggle = on_straggle

    def observe(self, step: int, dt: float):
        if len(self.times) >= self.warmup:
            med = statistics.median(self.times[-50:])
            if dt > self.factor * med:
                self.straggles.append(step)
                if self.on_straggle:
                    self.on_straggle(step, dt)
        self.times.append(dt)

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0


class RunGuard:
    """Retry loop: run step_fn under failure containment + restore."""

    def __init__(self, restore_fn: Callable[[], int], max_restarts: int = 5):
        self.restore_fn = restore_fn
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(self, step: int, fn: Callable[[], None]) -> int:
        """Execute fn(); on failure restore and return the restored step.
        Returns the next step to run."""
        try:
            fn()
            return step + 1
        except Exception as e:  # noqa: BLE001 — containment boundary
            self.restarts += 1
            if self.restarts > self.max_restarts:
                raise
            print(f"[fault] step {step}: {e!r} -> restoring "
                  f"(restart {self.restarts}/{self.max_restarts})", flush=True)
            restored = self.restore_fn()
            return restored


def heartbeat_file(path: str, step: int):
    """Liveness marker for an external watchdog (pod-level restart policy)."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(f"{step} {time.time()}\n")
    os.replace(tmp, path)
