"""Assigned (architecture x input-shape) cells and their abstract inputs.

Shapes (per the brief):
    train_4k     seq 4096,   global_batch 256  -> train_step
    prefill_32k  seq 32768,  global_batch 32   -> prefill_step
    decode_32k   seq 32768,  global_batch 128  -> serve_step (1 new token)
    long_500k    seq 524288, global_batch 1    -> serve_step; ONLY for
                 sub-quadratic archs (falcon-mamba, recurrentgemma); the 8
                 full-attention archs skip it (recorded in DESIGN.md).

VLM/audio: the modality frontend is a stub — ``input_specs`` carves
``num_prefix_embeds`` positions out of the sequence and supplies them as
precomputed f32 embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.configs import get_config, list_archs
from repro.configs.base import ModelConfig, RunConfig
from repro.core.policy import QuantPolicy

SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

DEFAULT_SERVE_QUANT = QuantPolicy(scheme="fp5.33-e2m3", strategy="set_lsb",
                                  impl="ref")


def shapes_for(cfg: ModelConfig) -> List[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        out.append("long_500k")
    return out


def all_cells() -> List[Tuple[str, str]]:
    cells = []
    for arch in list_archs():
        cfg = get_config(arch)
        cells.extend((arch, s) for s in shapes_for(cfg))
    return cells


def make_run_config(arch: str, shape: str, *,
                    quant: QuantPolicy | None = None,
                    **overrides) -> RunConfig:
    cfg = get_config(arch)
    seq, batch, mode = SHAPES[shape]
    q = None
    if mode in ("prefill", "decode"):
        q = quant if quant is not None else DEFAULT_SERVE_QUANT
    rc = RunConfig(model=cfg, seq_len=seq, global_batch=batch, mode=mode,
                   quant=q)
    if overrides:
        rc = dataclasses.replace(rc, **overrides)
    return rc
