"""On-device stochastic sampling for the continuous-batching engine.

The engine's jitted step ends in a per-slot token draw. PR 1-4 hard-coded
greedy argmax; this module generalizes it to per-request temperature /
top-k / top-p sampling with per-request termination (EOS / stop tokens /
length cap) — still ON DEVICE, so the per-tick host traffic stays [B]
int32 tokens plus a [B] done flag, never the [B, V] logits.

Contracts (load-bearing — tests/test_sampling.py pins all three):

  * ``temperature == 0`` IS greedy: the draw lowers to the exact
    ``jnp.argmax`` the engine has always used (top_k/top_p are ignored at
    temperature 0), and an all-greedy batch takes a ``lax.cond`` branch
    that is *only* the argmax — so greedy workloads pay nothing for the
    sampling machinery and every stream-equivalence guarantee (engine ≡
    one-shot, chunked ≡ unchunked, prefix-cache on ≡ off) keeps holding
    bit-identically.

  * PRNG key discipline: each draw uses
    ``fold_in(fold_in(PRNGKey(params.seed), request_id), n_generated)``.
    The request id and the request's OWN generated-token index are the
    only fold inputs — never the slot index, engine tick, or batch
    neighbours — so a seeded stream replays bit-identically across engine
    restarts, slot reassignment, different slot counts, and different
    prefill chunking. The request-level half (``request_key``) is folded
    once host-side at submit; the per-draw half folds in-step from the
    slot's generated count.

  * Termination is decided in-step: ``done = stop_token_hit | (n_generated
    + 1 >= max_tokens)``. Stop ids ride a fixed-width [B, MAX_STOP] int32
    row (padded with -1, an id no token matches); the stop token itself is
    appended to the stream before the request finishes.

Transform order per row (matching vLLM/HF conventions): scale by
temperature, mask to top-k, mask to top-p (nucleus, computed on the
tempered distribution), categorical draw. Ties at the k-th / nucleus
cutoff keep every tied candidate — deterministic, and independent of sort
stability.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# fixed width of the per-slot stop-id row the jitted step consumes;
# SamplingParams rejects longer stop sets at construction
MAX_STOP_IDS = 8

# pad value for unused stop-id lanes: no sampled token is ever negative
_NO_STOP = -1


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling + termination configuration.

    temperature  0.0 = greedy argmax (exact; top_k/top_p ignored)
    top_k        keep the k highest logits (0 = disabled)
    top_p        nucleus: keep the smallest prefix of the sorted
                 distribution with cumulative mass >= top_p (1.0 = off)
    seed         request-level PRNG seed (folded with the request id)
    max_tokens   length cap; None = resolved from the submit() argument
    stop_token_ids  sampling one of these ends the request (EOS lives
                 here); the stop token is included in the output stream
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    max_tokens: Optional[int] = None
    stop_token_ids: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if self.max_tokens is not None and self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")
        ids = tuple(int(t) for t in self.stop_token_ids)
        if len(ids) > MAX_STOP_IDS:
            raise ValueError(
                f"at most {MAX_STOP_IDS} stop_token_ids supported, got {len(ids)}")
        if any(t < 0 for t in ids):
            raise ValueError(f"stop_token_ids must be non-negative, got {ids}")
        object.__setattr__(self, "stop_token_ids", ids)

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


GREEDY = SamplingParams()


def request_key(seed: int, rid: int) -> np.ndarray:
    """Host-side request-level key: fold_in(PRNGKey(seed), rid) as raw
    uint32[2] data. Computed once at submit; the per-draw fold happens
    in-step from the generated-token count."""
    return np.asarray(
        jax.random.fold_in(jax.random.PRNGKey(seed), rid), np.uint32)


def slot_batch(n_slots: int) -> dict:
    """The host-side per-slot sampling state the engine maintains and
    ships to the step each tick (one pytree arg). Idle-slot rows are
    harmless defaults (greedy, never-stopping, zero key)."""
    return {
        "key": np.zeros((n_slots, 2), np.uint32),
        "ngen": np.zeros(n_slots, np.int32),
        "temperature": np.zeros(n_slots, np.float32),
        "top_k": np.zeros(n_slots, np.int32),
        "top_p": np.ones(n_slots, np.float32),
        "max_tokens": np.full(n_slots, np.iinfo(np.int32).max, np.int32),
        "stop_ids": np.full((n_slots, MAX_STOP_IDS), _NO_STOP, np.int32),
    }


def fill_slot(batch: dict, slot: int, params: SamplingParams,
              key_data: np.ndarray, max_tokens: int) -> None:
    """Write one request's resolved sampling state into its slot row."""
    batch["key"][slot] = key_data
    batch["ngen"][slot] = 0
    batch["temperature"][slot] = params.temperature
    batch["top_k"][slot] = params.top_k
    batch["top_p"][slot] = params.top_p
    batch["max_tokens"][slot] = max_tokens
    batch["stop_ids"][slot] = _NO_STOP
    if params.stop_token_ids:
        batch["stop_ids"][slot, :len(params.stop_token_ids)] = \
            params.stop_token_ids


def clear_slot(batch: dict, slot: int) -> None:
    """Reset a freed slot row to the idle defaults."""
    batch["key"][slot] = 0
    batch["ngen"][slot] = 0
    batch["temperature"][slot] = 0.0
    batch["top_k"][slot] = 0
    batch["top_p"][slot] = 1.0
    batch["max_tokens"][slot] = np.iinfo(np.int32).max
    batch["stop_ids"][slot] = _NO_STOP


def batch_shapes(n_slots: int) -> dict:
    """Abstract shapes of the step's sampling pytree arg (dry-run/AOT)."""
    return {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype)
        for k, v in slot_batch(n_slots).items()
    }


# ---------------------------------------------------------------------------
# device-side transforms
# ---------------------------------------------------------------------------
def _mask_top_k(logits, k):
    """REFERENCE top-k mask: keep the k highest logits (ties at the cutoff
    included); k <= 0 disables. Per-row, [V] -> [V] with dropped entries
    at -inf. The hot path is `_masked_logits` (one shared sort); unit
    tests pin both and their equivalence."""
    v = logits.shape[-1]
    sorted_desc = jnp.sort(logits)[::-1]
    kth = sorted_desc[jnp.clip(k, 1, v) - 1]
    kth = jnp.where(k > 0, kth, -jnp.inf)
    return jnp.where(logits >= kth, logits, -jnp.inf)


def _mask_top_p(logits, p):
    """REFERENCE nucleus mask: keep the smallest prefix of the descending-
    sorted distribution whose cumulative probability reaches p (the top
    token always survives; ties at the cutoff included). p >= 1 disables."""
    sorted_desc = jnp.sort(logits)[::-1]
    probs = jax.nn.softmax(sorted_desc)
    csum = jnp.cumsum(probs)
    # position i survives iff the mass strictly before it is < p
    keep = (csum - probs) < p
    n_keep = jnp.maximum(jnp.sum(keep), 1)
    cutoff = sorted_desc[n_keep - 1]
    cutoff = jnp.where(p >= 1.0, -jnp.inf, cutoff)
    return jnp.where(logits >= cutoff, logits, -jnp.inf)


def _masked_logits(scaled, top_k, top_p):
    """Fused top-k + top-p mask from ONE descending sort.

    Both transforms are >=-threshold masks on the same values, so their
    composition is a mask at max(top-k cutoff, top-p cutoff); computing
    the nucleus on the k-prefix of the shared sorted row matches
    `_mask_top_p(_mask_top_k(x))` exactly (the survivors of top-k are a
    prefix of the descending sort). One O(V log V) sort per sampled row
    instead of two — this runs per slot per tick on the decode hot path."""
    v = scaled.shape[-1]
    pos = jnp.arange(v)
    sorted_desc = jnp.sort(scaled)[::-1]
    kth = sorted_desc[jnp.clip(top_k, 1, v) - 1]
    kth = jnp.where(top_k > 0, kth, -jnp.inf)
    n_k = jnp.sum(sorted_desc >= kth)          # k-prefix length (ties incl.)
    probs = jax.nn.softmax(jnp.where(pos < n_k, sorted_desc, -jnp.inf))
    csum = jnp.cumsum(probs)
    keep = (csum - probs) < top_p              # mass strictly before i < p
    n_keep = jnp.maximum(jnp.sum(keep), 1)
    p_cut = jnp.where(top_p >= 1.0, -jnp.inf, sorted_desc[n_keep - 1])
    return jnp.where(scaled >= jnp.maximum(kth, p_cut), scaled, -jnp.inf)


def _sample_row(logits, key, temperature, top_k, top_p):
    """One slot's draw: tempered + masked categorical, with an exact
    argmax override at temperature 0 (transforms skipped entirely)."""
    greedy_tok = jnp.argmax(logits)
    safe_t = jnp.where(temperature > 0, temperature, 1.0)
    scaled = logits.astype(jnp.float32) / safe_t
    sampled = jax.random.categorical(key, _masked_logits(scaled, top_k, top_p))
    return jnp.where(temperature > 0, sampled, greedy_tok).astype(jnp.int32)


def sample_tokens(logits, sampling: dict):
    """The step's epilogue: per-slot token draw + in-step termination.

    logits: [B, V] (any float dtype); sampling: the `slot_batch` pytree
    (device arrays under jit). Returns (next_token [B] int32, done [B]
    bool). An all-greedy batch short-circuits to pure argmax via lax.cond,
    so greedy ticks never execute the sort-heavy masking path.
    """
    def draw_greedy(lg):
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)

    def draw_sampled(lg):
        keys = jax.vmap(jax.random.fold_in)(sampling["key"], sampling["ngen"])
        return jax.vmap(_sample_row)(
            lg, keys, sampling["temperature"], sampling["top_k"],
            sampling["top_p"])

    all_greedy = jnp.all(sampling["temperature"] <= 0.0)
    next_token = jax.lax.cond(all_greedy, draw_greedy, draw_sampled, logits)
    stop_hit = jnp.any(
        next_token[:, None] == sampling["stop_ids"], axis=-1)
    length_hit = sampling["ngen"] + 1 >= sampling["max_tokens"]
    return next_token, stop_hit | length_hit
