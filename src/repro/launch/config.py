"""EngineConfig: the one constructor surface of the serving engine.

`ServeEngine` accreted ~18 loose keyword arguments across PRs 1-9 — cache
geometry, chunking, speculation, observability, mesh, sampling seeds — that
no stable client could program against. `EngineConfig` collapses them into
ONE frozen dataclass that composes the per-subsystem configs that already
existed (`repro.cache.CacheConfig`, `repro.obs.ObsConfig`, a serving mesh)
plus the engine-level scalars (slots/capacity/chunking/speculation), and
carries EVERY constructor-time validation in `__post_init__` so a bad
config fails in one place with one error surface, before any device work.

    from repro.serving import EngineConfig, ServeEngine

    cfg = EngineConfig(arch="qwen2-7b", scheme="fp5.33-e2m3",
                       slots=4, capacity=64,
                       cache=CacheConfig(kind="paged_ams"))
    eng = ServeEngine(cfg)

The legacy keyword constructor (``ServeEngine("qwen2-7b", slots=4, ...)``)
still works through `EngineConfig.from_legacy` — a deprecation shim pinned
(tests/test_engine_api.py) to produce an engine with an IDENTICAL
`engine_step_signature` and bit-identical token streams.

Derived values the engine used to compute inline (`step_chunk`, the
resolved per-tick token budget, the sized CacheConfig) are properties /
methods here, so the engine and the tests share one source of truth.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

from repro.cache import CacheConfig
from repro.obs import ObsConfig

# the legacy ServeEngine keyword surface from_legacy still accepts; kept
# explicit so an unknown kwarg fails loudly instead of being swallowed
LEGACY_KWARGS = (
    "reduced", "scheme", "strategy", "impl", "mesh_kind", "mesh", "slots",
    "capacity", "max_queue", "cache_config", "prefill_chunk", "token_budget",
    "speculate_k", "drafter", "obs", "seed", "verbose", "preempt",
)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Everything a `ServeEngine` needs, in one frozen value.

    Model / weights:
      arch        config name from `repro.configs` (e.g. "qwen2-7b")
      reduced     use the reduced (test-size) variant of the config
      scheme      weight quantization scheme ("fp16" = no weight quant)
      strategy    mantissa-sharing strategy for weight quantization
      impl        matmul/attention lowering: ref | fused_ref | pallas |
                  pallas_interpret
      seed        PRNG seed for (random-init) serving params

    Capacity / scheduling:
      slots        concurrent sequences in the jitted step
      capacity     per-sequence cache positions (prompt + generated - 1)
      max_queue    pending-queue bound; submit raises past it (HTTP 429)
      prefill_chunk  ragged multi-token prefill: up to C prompt tokens per
                  slot per tick (1 = one-position-per-tick step)
      token_budget  global per-tick token cap (None = slots * step_chunk)
      preempt      allow priority preemption: a strictly-higher-priority
                  queue head may evict a running lower-priority request,
                  spilling its private KV pages to the host tier (paged
                  modes; see docs/serving.md §Preemption)

    Composed subsystem configs:
      cache       `repro.cache.CacheConfig` (None = contiguous default);
                  sized to (slots, capacity) by `sized_cache()`
      obs         `repro.obs.ObsConfig` telemetry switchboard
      mesh        explicit serving mesh with a "model" axis (tensor-
                  parallel); None = the `mesh_kind` driver mesh
      mesh_kind   driver-mesh shape name when `mesh` is None

    Speculative decoding:
      speculate_k  score up to k draft tokens per decode round (0 = off)
      drafter      drafter name ("ngram" | "self" | "self-full") or a
                  `repro.launch.speculative.Drafter` instance

    verbose       print quantization timing at construction
    """

    arch: str = "qwen2-7b"
    reduced: bool = True
    scheme: str = "fp5.33-e2m3"
    strategy: str = "set_lsb"
    impl: str = "ref"
    seed: int = 0

    slots: int = 4
    capacity: int = 128
    max_queue: Optional[int] = None
    prefill_chunk: int = 1
    token_budget: Optional[int] = None
    preempt: bool = True

    cache: Optional[CacheConfig] = None
    obs: ObsConfig = dataclasses.field(default_factory=ObsConfig)
    mesh: Any = None
    mesh_kind: str = "none"

    speculate_k: int = 0
    drafter: Any = "ngram"

    verbose: bool = False

    # ------------------------------------------------------------ validation
    def __post_init__(self):
        # the ONE constructor-time error surface: every check the engine
        # used to scatter through __init__ lives here (and only here)
        if not self.arch or not isinstance(self.arch, str):
            raise ValueError(f"arch must be a config name, got {self.arch!r}")
        from repro.configs import get_config, list_archs
        try:
            get_config(self.arch)
        except KeyError:
            raise ValueError(
                f"unknown arch {self.arch!r}; one of "
                f"{list_archs(assigned_only=False)}") from None
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")
        if self.prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {self.prefill_chunk}")
        if self.speculate_k < 0:
            raise ValueError(
                f"speculate_k must be >= 0, got {self.speculate_k}")
        if self.token_budget is not None and self.token_budget < 1:
            raise ValueError(
                f"token_budget must be >= 1, got {self.token_budget}")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(
                f"max_queue must be >= 1 (or None = unbounded), "
                f"got {self.max_queue}")
        if self.mesh is not None and "model" not in self.mesh.axis_names:
            raise ValueError("ServeEngine mesh needs a 'model' axis")
        if self.cache is not None and not isinstance(self.cache, CacheConfig):
            raise TypeError(
                f"cache must be a CacheConfig, got {type(self.cache).__name__}")
        if not isinstance(self.obs, ObsConfig):
            raise TypeError(
                f"obs must be an ObsConfig, got {type(self.obs).__name__}")

    # --------------------------------------------------------------- derived
    @property
    def step_chunk(self) -> int:
        """Token-buffer width of the jitted step: the prefill chunk, widened
        to hold 1 fed token + k drafts per slot when speculating."""
        if self.speculate_k:
            return max(self.prefill_chunk, self.speculate_k + 1)
        return self.prefill_chunk

    @property
    def resolved_token_budget(self) -> int:
        """The per-tick token budget actually enforced (default: no
        throttling — every slot can fill its chunk)."""
        if self.token_budget is not None:
            return self.token_budget
        return self.slots * self.step_chunk

    def sized_cache(self) -> CacheConfig:
        """The CacheConfig the engine runs: the composed one (or the
        contiguous default), with derived pool sizes filled from
        (slots, capacity) for paged modes."""
        ccfg = self.cache if self.cache is not None else CacheConfig()
        if ccfg.paged:
            ccfg = ccfg.sized(capacity=self.capacity, slots=self.slots)
        return ccfg

    def replace(self, **changes) -> "EngineConfig":
        return dataclasses.replace(self, **changes)

    # ------------------------------------------------------------ legacy shim
    @classmethod
    def from_legacy(cls, arch: Optional[str] = None, *,
                    _warn: bool = True, **kwargs) -> "EngineConfig":
        """Build an EngineConfig from the pre-redesign ``ServeEngine(arch,
        **kwargs)`` keyword surface. Deprecated: new code passes an
        EngineConfig. Pinned to produce an identical
        `engine_step_signature` (tests/test_engine_api.py)."""
        unknown = set(kwargs) - set(LEGACY_KWARGS)
        if unknown:
            raise TypeError(
                f"unknown ServeEngine argument(s) {sorted(unknown)}; "
                f"see repro.launch.config.EngineConfig")
        if _warn:
            warnings.warn(
                "ServeEngine(arch, **kwargs) is deprecated; pass "
                "ServeEngine(EngineConfig(...)) — see repro.serving",
                DeprecationWarning, stacklevel=3)
        if "cache_config" in kwargs:
            kwargs["cache"] = kwargs.pop("cache_config")
        fields = {}
        if arch is not None:
            fields["arch"] = arch
        for k, v in kwargs.items():
            if v is None and k in ("obs",):
                continue                      # keep the dataclass default
            fields[k] = v
        return cls(**fields)
