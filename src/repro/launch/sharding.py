"""Parameter/cache PartitionSpec rules (Megatron TP pairing + FSDP).

Name-driven: every linear in the model zoo is classified column-parallel
(output dim over `model`) or row-parallel (input dim over `model`), so that
activations alternate sharded -> psum-replicated exactly once per block pair
and never reshard mid-block. FSDP additionally shards the *other* weight dim
over `data` during training (XLA turns that into the standard all-gather-
before-use / reduce-scatter-of-grads pattern).

Quantized (serving) params have planes `hi`/`lsb` [.., K_packed_rows, N] and
`scale` [.., N]; they follow the same col/row classification — N over model
for column-parallel, packed-K rows over model for row-parallel — and are
never FSDP-sharded (decode wants weights resident).
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# output dim (N) sharded over model
COL_PARALLEL = {
    "wq", "wk", "wv", "w_gate", "w_up", "in_proj", "in_x", "in_gate",
    "wq_b", "w_uk", "w_uv", "dt_proj", "lm_head",
}
# input dim (K) sharded over model (output psum-replicated)
ROW_PARALLEL = {"wo", "w_down", "out_proj", "x_proj", "w_rec_gate", "w_in_gate"}
# never sharded over model (small / accuracy-critical)
REPLICATED = {"router", "wq_a", "wkv_a"}

# 1D vectors living in the model-sharded inner width
MODEL_VECTORS = {"A_log", "D", "lam", "conv_b"}


def _path_names(path):
    return [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]


def param_spec(path, leaf, *, fsdp: Optional[str], tp: str = "model",
               n_stack: int = 0, moe: str = "ep",
               serve_n_shard: bool = False) -> P:
    """PartitionSpec for one parameter leaf.

    n_stack: number of leading stacked dims (layer-scan G).
    moe: 'ep' shards the expert dim over `model` (serving / expert-parallel);
         'tp' leaves experts unsharded and TP-shards each expert's FFN dims
         like a dense FFN (training path — see models/moe.py:moe_tp).
    serve_n_shard: the ENGINE-STEP layout — classify row-parallel linears
         column-style too, so plain ``w``/``b`` leaves follow the same
         N-over-model rule the packed quantized planes already use. Every
         decode contraction then keeps its K dim device-complete, which is
         what makes sharded streams bit-identical to single-device streams
         (no split f32 reductions, no psum): the only cross-device traffic
         is an exact all-gather of decode-sized activations."""
    names = _path_names(path)
    last = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    gparent = names[-3] if len(names) >= 3 else ""
    is_expert = "experts" in names
    ndim = leaf.ndim

    lead: tuple = ()
    if n_stack:
        lead = (None,) * n_stack
    if is_expert:
        if moe == "tp":
            lead = lead + (None,)  # expert dim replicated; FFN dims TP'd
            is_expert = False
        else:
            lead = lead + (tp,)    # expert dim over model (EP)

    body_nd = ndim - len(lead)

    def cls(name: str) -> str:
        if name in COL_PARALLEL:
            return "col"
        if name in ROW_PARALLEL:
            return "col" if serve_n_shard else "row"
        return "rep"

    # --- packed quantized planes: parent is the linear name.
    # Serving layout: ALWAYS shard the output-channel dim N over model
    # (column-style), including row-parallel linears — packed-K rows are not
    # generally divisible by tp, and at decode batch sizes the extra
    # activation all-gather is nanoscale next to the weight-bytes win.
    if last in ("hi", "lsb", "scale"):
        ep_tp = None if is_expert else tp  # EP: expert dim already uses model
        if last == "scale":
            return P(*lead, ep_tp)
        return P(*lead, None, ep_tp)

    # --- plain weights / biases
    if last == "w":
        c = cls(parent)
        if parent == "embed" or gparent == "embed":
            return P(*lead, tp, None)  # vocab over model
        ep_tp = None if is_expert else tp
        if body_nd != 2:
            return P(*lead, *([None] * body_nd))
        # §Perf: FSDP-sharding a SMALL contraction dim (MLA/LoRA factors,
        # dt_proj...) makes the SPMD partitioner emit partial-sum all-reduces
        # of full activations/attention scores instead of cheap weight
        # gathers (measured: 9.6TB/step of score all-reduces on minicpm3
        # train_4k). Factors with any dim < 1024 are cheap to keep unsharded.
        wf = fsdp if min(leaf.shape[-2:]) >= 1024 else None
        if c == "col":
            return P(*lead, wf, ep_tp)
        if c == "row":
            return P(*lead, ep_tp, wf)
        return P(*lead, wf, None)
    if last == "b":
        c = cls(parent)
        ep_tp = None if is_expert else tp
        return P(*lead, ep_tp if c == "col" else None)

    # --- SSM/LRU vectors & conv kernels in the model-sharded width
    if last in MODEL_VECTORS:
        if last == "A_log":
            return P(*lead, tp, None)
        if last == "conv_b":
            return P(*lead, tp)
        return P(*lead, tp)
    if last == "conv_w":
        return P(*lead, None, tp)

    # norms, scalars, everything else: replicated
    return P(*lead, *([None] * body_nd))


def params_shardings(params_shape, mesh, *, fsdp: bool, stacked_key="layers",
                     moe: str = "ep", serve_n_shard: bool = False):
    """Pytree of NamedSharding matching a params(-shaped) pytree."""
    fsdp_axis = "data" if fsdp else None

    def visit(path, leaf):
        names = _path_names(path)
        n_stack = 1 if names and names[0] == stacked_key else 0
        spec = param_spec(path, leaf, fsdp=fsdp_axis, n_stack=n_stack, moe=moe,
                          serve_n_shard=serve_n_shard)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(visit, params_shape)


def cache_spec(path, leaf, *, dp, tp: str = "model", seq_shard: bool,
               n_stack: int = 0) -> P:
    """KV/state cache sharding. dp: axis (tuple) for batch or None."""
    names = _path_names(path)
    last = names[-1]
    lead = (None,) * n_stack
    if last in ("k", "v", "kv"):
        # [.., B, S, kv, hd]
        return P(*lead, dp, tp if seq_shard else None, None, None)
    if last == "conv":
        return P(*lead, dp, None, tp)
    if last == "ssm":
        return P(*lead, dp, tp, None)
    if last == "state":
        return P(*lead, dp, tp)
    return P(*lead, *([None] * (leaf.ndim - n_stack)))


def cache_shardings(cache_shape, mesh, *, dp, seq_shard: bool,
                    stacked_key="layers"):
    def visit(path, leaf):
        names = _path_names(path)
        n_stack = 1 if names and names[0] == stacked_key else 0
        spec = cache_spec(path, leaf, dp=dp, seq_shard=seq_shard,
                          n_stack=n_stack)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(visit, cache_shape)


def pool_spec(leaf, tp: str = "model") -> P:
    """PartitionSpec for one paged-pool plane, HEAD-SHARDED over `model`.

    Every pool leaf — bf16 ``k``/``v`` [.., num_pages, page, kv, hd] and the
    packed AMS ``hi``/``lsb``/``scale`` planes alike — carries the kv-head
    dim at axis ndim-2, so one rule shards them all: split kv heads over the
    model axis, keep pages / page rows / packed words whole. Page ids stay
    head-dimension-free, which is why the host-side allocator, prefix-cache
    index and block tables never see the mesh."""
    return P(*([None] * (leaf.ndim - 2)), tp, None)


def pool_shardings(cache_shape, mesh, tp: str = "model"):
    """NamedShardings for a paged cache pytree: kv heads over `model` when
    they divide the axis size, replicated otherwise (tp=1, or a head count
    the mesh cannot split — correctness never depends on divisibility)."""
    ntp = mesh.shape[tp] if tp in mesh.axis_names else 1

    def visit(leaf):
        if ntp > 1 and leaf.ndim >= 2 and leaf.shape[-2] % ntp == 0:
            return NamedSharding(mesh, pool_spec(leaf, tp))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(visit, cache_shape)
