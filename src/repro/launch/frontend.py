"""Async serving front end: stdlib-only HTTP/1.1 + SSE over the engine.

One asyncio event loop owns BOTH sides of the server:

  * connection handlers parse requests and enqueue work through
    ``ServeEngine.submit`` (token-id prompts in, token streams out), and
  * a single driver task ticks the engine through the split step —
    ``step_begin`` dispatches tick t's jitted step asynchronously, the
    driver yields back to the loop, and ``step_end`` blocks on the device
    outputs. The yield between the halves is the double-buffering seam:
    while the device computes tick t, the loop serves HTTP reads, SSE
    writes, and new submissions, so tick t+1's work is queued before t's
    same-tick re-admit runs.

No external dependencies: the HTTP layer is a few dozen lines over
``asyncio.start_server`` (keep-alive off, one request per connection),
which is all the Poisson-overload benchmark and the API tests need.

Endpoints
---------
  POST /v1/generate   {"prompt": [ids], "max_tokens": n, "priority": p,
                       "temperature"/"top_k"/"top_p"/"seed"/"stop": ...,
                       "stream": false}
                      -> JSON {"tokens": [...], "finish_reason": ...}
                      stream=true -> SSE, one data: event per token
  GET  /healthz       -> {"ok": true, "tick": ..., "active": ...}
  GET  /metrics       -> Prometheus text exposition (repro.obs.metrics)

Queue-full submissions return 429 so open-loop load generators see
backpressure instead of unbounded queueing.

    cfg = EngineConfig(cache=CacheConfig(kind="paged_ams"))
    asyncio.run(ServeFrontend(ServeEngine(cfg)).serve_forever())
"""

from __future__ import annotations

import asyncio
import concurrent.futures
import json
from typing import Dict, Optional, Tuple

import numpy as np

from repro.launch.sampling import SamplingParams

_MAX_HEADER = 64 * 1024
_MAX_BODY = 8 * 1024 * 1024


def _http(status: int, body: bytes, ctype: str = "application/json") -> bytes:
    reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
              405: "Method Not Allowed", 429: "Too Many Requests",
              500: "Internal Server Error"}.get(status, "OK")
    return (f"HTTP/1.1 {status} {reason}\r\n"
            f"Content-Type: {ctype}\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n").encode() + body


def _json_body(status: int, obj) -> bytes:
    return _http(status, json.dumps(obj).encode())


class ServeFrontend:
    """Async HTTP front end over one `ServeEngine`.

    The frontend owns the engine's driver loop for its lifetime: it sets
    ``eng.driver_active`` so RequestHandle waiters (``result``/``stream``)
    park on the tick condition variable instead of stepping the engine
    themselves, and every engine mutation (submit is thread-safe enqueue;
    step halves run in a worker thread one-at-a-time) stays serialized.
    """

    def __init__(self, engine, host: str = "127.0.0.1", port: int = 0,
                 idle_poll_s: float = 0.02):
        self.eng = engine
        self.host = host
        self.port = port              # 0 = ephemeral; real port after start()
        self.idle_poll_s = idle_poll_s
        self._server: Optional[asyncio.AbstractServer] = None
        self._driver_task: Optional[asyncio.Task] = None
        self._work = asyncio.Event()
        self._running = False

    # --------------------------------------------------------------- lifecycle
    async def start(self) -> None:
        """Bind the listener and start the engine driver task."""
        self._running = True
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._driver_task = asyncio.create_task(self._driver())

    async def stop(self) -> None:
        self._running = False
        self._work.set()
        if self._driver_task is not None:
            await self._driver_task
            self._driver_task = None
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    async def serve_forever(self) -> None:
        await self.start()
        try:
            async with self._server:
                await self._server.serve_forever()
        finally:
            await self.stop()

    # ------------------------------------------------------------------ driver
    async def _driver(self) -> None:
        """Tick the engine whenever it has work, through the split step.

        Both halves run in a worker thread (they touch numpy/JAX host
        state); the explicit yield between them is where tick t+1's HTTP
        traffic overlaps tick t's device compute.
        """
        eng = self.eng
        eng.driver_active = True
        loop = asyncio.get_running_loop()
        # dedicated single thread: handler-side to_thread() calls (result()
        # waiters) can saturate the default pool, and the driver must never
        # queue behind the very waiters it unblocks
        pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="engine-step")
        try:
            while self._running:
                if not eng.has_work:
                    self._work.clear()
                    if not eng.has_work:      # re-check after clear: no lost wakeup
                        try:
                            await asyncio.wait_for(self._work.wait(),
                                                   timeout=self.idle_poll_s)
                        except asyncio.TimeoutError:
                            pass
                        continue
                pending = await loop.run_in_executor(pool, eng.step_begin)
                # device computes tick t here; drain the event loop once so
                # reads/writes/submissions land before the blocking half
                await asyncio.sleep(0)
                await loop.run_in_executor(pool, eng.step_end, pending)
        finally:
            eng.driver_active = False
            pool.shutdown(wait=False)

    # -------------------------------------------------------------------- http
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            method, path, body = await self._read_request(reader)
            if method is None:
                return
            await self._route(method, path, body, writer)
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        except Exception as e:          # surface handler bugs to the client
            try:
                writer.write(_json_body(500, {"error": repr(e)}))
                await writer.drain()
            except Exception:
                pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    async def _read_request(self, reader) -> Tuple[Optional[str], str, bytes]:
        head = await reader.readuntil(b"\r\n\r\n")
        if len(head) > _MAX_HEADER:
            return None, "", b""
        lines = head.decode("latin-1").split("\r\n")
        try:
            method, path, _ = lines[0].split(" ", 2)
        except ValueError:
            return None, "", b""
        clen = 0
        for ln in lines[1:]:
            if ln.lower().startswith("content-length:"):
                clen = int(ln.split(":", 1)[1].strip())
        if clen > _MAX_BODY:
            return None, "", b""
        body = await reader.readexactly(clen) if clen else b""
        return method, path, body

    async def _route(self, method: str, path: str, body: bytes,
                     writer: asyncio.StreamWriter) -> None:
        eng = self.eng
        if path == "/healthz" and method == "GET":
            writer.write(_json_body(200, {
                "ok": True, "tick": eng.tick, "active": eng.active_count,
                "queue_depth": eng.sched.queue_depth}))
            await writer.drain()
            return
        if path == "/metrics" and method == "GET":
            writer.write(_http(200, eng.metrics.exposition().encode(),
                               ctype="text/plain; version=0.0.4"))
            await writer.drain()
            return
        if path == "/v1/generate":
            if method != "POST":
                writer.write(_json_body(405, {"error": "POST only"}))
                await writer.drain()
                return
            await self._generate(body, writer)
            return
        writer.write(_json_body(404, {"error": f"no route {path}"}))
        await writer.drain()

    # ---------------------------------------------------------------- generate
    def _parse_generate(self, body: bytes):
        req = json.loads(body.decode())
        prompt = req.get("prompt")
        if (not isinstance(prompt, list) or not prompt
                or not all(isinstance(t, int) for t in prompt)):
            raise ValueError("'prompt' must be a non-empty list of token ids")
        kw: Dict[str, object] = {}
        for k in ("temperature", "top_k", "top_p", "seed"):
            if k in req:
                kw[k] = req[k]
        if "stop" in req:
            kw["stop_token_ids"] = tuple(req["stop"])
        sampling = SamplingParams(**kw)
        return (np.asarray(prompt, np.int32), int(req.get("max_tokens", 16)),
                int(req.get("priority", 0)), bool(req.get("stream", False)),
                sampling)

    async def _generate(self, body: bytes,
                        writer: asyncio.StreamWriter) -> None:
        eng = self.eng
        try:
            prompt, max_tokens, priority, stream, sampling = \
                self._parse_generate(body)
        except (ValueError, TypeError, json.JSONDecodeError) as e:
            writer.write(_json_body(400, {"error": str(e)}))
            await writer.drain()
            return
        try:
            handle = eng.submit(prompt, max_tokens=max_tokens,
                                sampling=sampling, priority=priority)
        except RuntimeError as e:       # admission backpressure: queue full
            writer.write(_json_body(429, {"error": str(e)}))
            await writer.drain()
            self._work.set()
            return
        self._work.set()                # wake the driver for the new request
        if not stream:
            tokens = await asyncio.to_thread(handle.result)
            writer.write(_json_body(200, {
                "rid": handle.request.rid, "tokens": tokens,
                "finish_reason": handle.request.finish_reason,
                "preemptions": handle.request.preemptions}))
            await writer.drain()
            return
        # SSE: one event per generated token, then a done event carrying the
        # finish reason — the per-token writes are what the double-buffered
        # driver overlaps with device compute
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()
        i = 0
        async for tok in handle.stream():
            writer.write(f"data: {json.dumps({'token': tok, 'index': i})}\n\n"
                         .encode())
            await writer.drain()
            i += 1
        done = {"finish_reason": handle.request.finish_reason,
                "n_tokens": len(handle.request.tokens),
                "preemptions": handle.request.preemptions}
        writer.write(f"event: done\ndata: {json.dumps(done)}\n\n".encode())
        await writer.drain()


def serve(config, host: str = "127.0.0.1", port: int = 8000,
          params=None) -> None:
    """Blocking convenience entry point: build the engine from an
    `EngineConfig` and serve until interrupted."""
    from repro.launch.engine import ServeEngine
    eng = ServeEngine(config, params=params)
    asyncio.run(ServeFrontend(eng, host=host, port=port).serve_forever())


def main(argv=None) -> None:
    import argparse

    from repro.cache import CacheConfig
    from repro.launch.config import EngineConfig

    ap = argparse.ArgumentParser(
        description="HTTP/SSE serving front end over the engine")
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--scheme", default="fp5.33-e2m3")
    ap.add_argument("--impl", default="ref")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--chunk", type=int, default=1)
    ap.add_argument("--max-queue", type=int, default=None)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--cache", default="paged_ams",
                    choices=["contiguous", "paged_bf16", "paged_ams"])
    ap.add_argument("--host-spill-pages", type=int, default=0)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    a = ap.parse_args(argv)
    cache = (None if a.cache == "contiguous" else
             CacheConfig(kind=a.cache, page_size=a.page_size,
                         host_spill_pages=a.host_spill_pages))
    serve(EngineConfig(arch=a.arch, reduced=a.reduced, scheme=a.scheme,
                       impl=a.impl, slots=a.slots, capacity=a.capacity,
                       prefill_chunk=a.chunk, max_queue=a.max_queue,
                       cache=cache, verbose=True),
          host=a.host, port=a.port)


if __name__ == "__main__":
    main()
