"""Request queue + slot admission for the continuous-batching engine.

The scheduler owns the *host-side* half of serving state: a priority queue
of pending requests and the mapping of requests into free slots of the
fixed-capacity KV cache. Admission is capacity-safe by construction — a
request is only accepted at submit time if its full footprint (prefix
embeddings + prompt + generated tokens) fits one cache slot.

Policy: priority classes over strict arrival order. Every request carries
an integer ``priority`` (higher = more urgent, default 0); the queue is
ordered by (priority desc, arrival order asc), so an all-default workload
degenerates to EXACTLY the strict FIFO of PRs 1–9 (pinned by the existing
engine tests). A preempted request re-enters via ``requeue`` AHEAD of every
waiting request of its priority class (it already consumed service, and it
holds spilled state that should drain quickly), but still behind any
strictly-higher class.

For the paged KV cache the engine passes ``admit(..., fits=...)`` — the
CACHE-AWARE free-page budget check: it matches the request's prompt-page
hashes against the allocator's prefix index (longest resident prefix) and
charges only the UNCACHED page count against the free budget, so a request
whose prompt is mostly cached admits even under page pressure. Queue order
is preserved by head-of-line blocking (a queue head that doesn't fit stops
admission rather than being jumped); under the engine's preemption policy
(`EngineConfig.preempt`) a blocked head of strictly higher priority
triggers victim preemption in the ENGINE, which spills the victim's pages
host-side and calls ``requeue`` — the scheduler itself never touches device
state. Because ``fits`` returning True guarantees admission, the engine's
check allocates pages directly — the matched prefix is pinned
(refcount += 1) and recorded as ``cached_len`` so the engine can skip
prefilling it.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

from repro.launch.sampling import GREEDY, SamplingParams
from repro.obs.metrics import NULL_REGISTRY

# Request.status lifecycle values (RequestHandle.status re-exports these):
#   queued -> prefill -> decode -> finished
#                 \______ preempted ______/   (back via requeue -> prefill)
QUEUED = "queued"
PREFILL = "prefill"
DECODE = "decode"
PREEMPTED = "preempted"
FINISHED = "finished"
REQUEST_STATUSES = (QUEUED, PREFILL, DECODE, PREEMPTED, FINISHED)


@dataclasses.dataclass
class SpilledState:
    """Host-side snapshot of a preempted request's in-flight state: exactly
    what the engine needs to resume it bit-identically — the device resume
    point, the next input token, and the released pages' content in the
    pool's PACKED storage layout (`cache.pool.extract_pages`), so AMS
    planes round-trip byte-exactly."""

    fed: int                 # cache positions already inserted (resume point)
    last_token: int          # next input token id to feed at position `fed`
    content: Any             # extract_pages pytree of the released pages
    n_pages: int             # released page count (page axis of `content`)
    n_keep: int              # shared-prefix pages that stayed pinned
    nbytes: int = 0          # host bytes the snapshot occupies (accounting)


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle inside the engine."""

    rid: int
    prompt: np.ndarray                    # [P] int32 token ids
    max_tokens: int                       # length CAP (stop tokens may end
    #                                       the stream earlier)
    prefix_embeds: Optional[np.ndarray] = None  # [n_prefix, D] f32 (VLM/audio)
    sampling: SamplingParams = GREEDY     # per-request sampling config
    key_data: Optional[np.ndarray] = None  # uint32[2] request-level PRNG key
    #                                        (fold_in(PRNGKey(seed), rid);
    #                                        engine-filled at submit)
    priority: int = 0                     # higher = more urgent; default 0
    #                                       everywhere = strict FIFO

    # lifecycle, filled by the scheduler/engine (tick = engine step index).
    # admit_tick can precede the first served tick by one: a slot freed by
    # an early-terminating request re-admits the SAME tick it frees (after
    # that tick's step already ran), so the admitted request's first chunk
    # runs at admit_tick + 1 — `first_step_tick` records the tick that
    # actually served it.
    submit_tick: int = -1
    admit_tick: int = -1
    first_step_tick: int = -1             # first tick whose step served us
    first_token_tick: int = -1            # tick that produced tokens[0]
    finish_tick: int = -1
    finish_reason: str = ""               # "stop" (EOS/stop id) | "length"
    slot: int = -1
    status: str = QUEUED                  # lifecycle (REQUEST_STATUSES)
    tokens: List[int] = dataclasses.field(default_factory=list)
    pages: List[int] = dataclasses.field(default_factory=list)  # paged mode

    # preemption (engine-filled; paged modes only):
    preemptions: int = 0                  # times this request was preempted
    spill: Optional[SpilledState] = None  # host snapshot while PREEMPTED

    # speculative decoding accounting (engine-filled; see launch.speculative)
    drafted: int = 0           # draft tokens scored for this request
    accepted_drafts: int = 0   # ... accepted by the verify rule

    # prefix caching (paged modes, engine-filled — see cache.allocator):
    page_hashes: Tuple[bytes, ...] = ()   # chain hash per FULL prompt page
    cached_len: int = 0    # positions served from shared pages at admission;
    #                        prefill starts at this position (prefill skip)
    published: int = 0     # prompt pages published to the prefix index so far

    # roofline attribution (engine-filled when ObsConfig.cost — see
    # repro.obs.cost): KV bytes this request's served tokens account for,
    # at the analytic floor vs what the cache implementation touches
    kv_floor_bytes: float = 0.0
    kv_achieved_bytes: float = 0.0

    def __post_init__(self):
        # the [P] int32 contract above is load-bearing: the engine feeds
        # prompt tokens straight into an int32 device buffer
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)

    @property
    def n_prefix(self) -> int:
        return 0 if self.prefix_embeds is None else self.prefix_embeds.shape[0]

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def kv_need(self) -> int:
        """WORST-CASE cache positions this request writes: every fed input
        inserts one KV entry; the last generated token is never fed back.
        Admission reserves this; a stop-token hit frees the unused tail
        early (the request ends before the length cap)."""
        return self.n_prefix + self.prompt_len + self.max_tokens - 1

    @property
    def n_generated(self) -> int:
        return len(self.tokens)

    @property
    def done(self) -> bool:
        return self.finish_tick >= 0

    @property
    def ttft_ticks(self) -> int:
        """Submit -> first generated token, in engine ticks (-1 if none yet).
        This is the headline number chunked prefill moves: prompt positions
        consumed per tick go from 1 to the chunk size."""
        if self.first_token_tick < 0:
            return -1
        return self.first_token_tick - self.submit_tick

    @property
    def prefill_ticks(self) -> int:
        """Ticks spent consuming the (uncached) prompt before the first
        generated token: ceil(uncached_prompt / chunk) by construction.
        Computed from the first SERVED tick, so it is invariant to whether
        admission happened at tick start or in the same-tick post-finish
        pass (-1 before the first token)."""
        if self.first_token_tick < 0:
            return -1
        return self.first_token_tick - self.first_step_tick + 1

    @property
    def kv_vs_floor(self) -> float:
        """KV read/write amplification for this request: bytes the cache
        implementation touched over the causal floor (0.0 until served
        with cost accounting on)."""
        if self.kv_floor_bytes <= 0:
            return 0.0
        return self.kv_achieved_bytes / self.kv_floor_bytes

    @property
    def latency_ticks(self) -> int:
        """Submit -> finish, in engine ticks (queueing included; -1 while
        in flight)."""
        if self.finish_tick < 0:
            return -1
        return self.finish_tick - self.submit_tick


class FIFOScheduler:
    """Priority admission into free KV-cache slots — (priority desc,
    arrival asc) order, which with all-default priorities is EXACTLY the
    strict FIFO this class shipped as in PRs 1–9 (hence the name).

    ``capacity`` is the per-slot sequence capacity of the engine's KV cache;
    ``max_queue`` (optional) bounds the pending queue — past it, ``submit``
    raises, which is the backpressure signal the frontend surfaces as 429.
    """

    def __init__(self, capacity: int, max_queue: Optional[int] = None,
                 metrics=None):
        self.capacity = capacity
        self.max_queue = max_queue
        # min-heap of (-priority, order, Request): order is a monotonic
        # submit counter, so equal priorities pop in arrival order; requeued
        # (preempted) requests take DECREASING negative orders, so they pop
        # ahead of every waiting request of their class
        self._queue: List[Tuple[int, int, Request]] = []
        self._order = 0
        self._rorder = 0
        # telemetry (repro.obs): the engine passes its registry; a bare
        # scheduler gets the shared no-op instruments
        m = metrics if metrics is not None else NULL_REGISTRY
        self._m_submitted = m.counter(
            "sched_requests_submitted_total", "requests accepted into the queue")
        self._m_rejected = m.counter(
            "sched_requests_rejected_total",
            "queue-full backpressure rejections (submit raised)")
        self._m_admitted = m.counter(
            "sched_requests_admitted_total", "requests placed into slots")
        self._m_blocked = m.counter(
            "sched_admit_blocked_total",
            "head-of-line blocks: the queue head failed the fits() gate")
        self._m_requeued = m.counter(
            "sched_requests_requeued_total",
            "preempted requests returned to the queue head")

    def submit(self, req: Request, tick: int) -> Request:
        if req.max_tokens < 1:
            raise ValueError(f"request {req.rid}: max_tokens must be >= 1")
        if req.prompt_len < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.kv_need > self.capacity:
            raise ValueError(
                f"request {req.rid} needs {req.kv_need} cache positions "
                f"(prefix {req.n_prefix} + prompt {req.prompt_len} + "
                f"{req.max_tokens} tokens - 1) but slot capacity is "
                f"{self.capacity}")
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            self._m_rejected.inc()
            raise RuntimeError(
                f"queue full ({self.max_queue}); request {req.rid} rejected")
        req.submit_tick = tick
        req.status = QUEUED
        self._order += 1
        heapq.heappush(self._queue, (-req.priority, self._order, req))
        self._m_submitted.inc()
        return req

    def requeue(self, req: Request) -> Request:
        """Return a PREEMPTED request to the queue, ahead of every waiting
        request of its priority class (it already consumed service and
        holds spilled pages that should drain) but behind any strictly
        higher class. Not subject to ``max_queue`` — rejecting a request
        we already accepted and part-served is not backpressure, it is
        data loss."""
        self._rorder -= 1
        heapq.heappush(self._queue, (-req.priority, self._rorder, req))
        self._m_requeued.inc()
        return req

    @property
    def head(self) -> Optional[Request]:
        """The request `admit` would place next (None when idle) — the
        engine's preemption policy compares its priority against the
        active slots'."""
        return self._queue[0][2] if self._queue else None

    def admit(self, free_slots: List[int], tick: int,
              fits: Optional[Callable[[Request], bool]] = None,
              max_admit: Optional[int] = None,
              ) -> List[Tuple[int, Request]]:
        """Assign queued requests to free slots, FIFO order. Returns
        (slot, request) pairs; the engine resets each slot's cache row
        before the request's first token is fed.

        ``fits(req)`` (optional) is an extra admission gate — the paged
        engine passes its cache-aware free-page budget check (longest
        resident prefix matched, only uncached pages charged; returning
        True also performs the page allocation, which is safe because True
        here guarantees the request is admitted). A queue head that does
        not fit BLOCKS admission (strict FIFO, no overtaking).

        ``max_admit`` (optional) caps admissions this tick — the chunked
        engine passes its remaining TOKEN budget headroom
        (token_budget - active slots), so the number of active slots never
        exceeds the per-tick token budget and every slot (decode slots
        included) is guaranteed to advance at least one token per tick no
        matter how many long prefills are chunking."""
        placed = []
        for slot in free_slots:
            if not self._queue:
                break
            if max_admit is not None and len(placed) >= max_admit:
                break
            if fits is not None and not fits(self._queue[0][2]):
                self._m_blocked.inc()
                break
            req = heapq.heappop(self._queue)[2]
            req.admit_tick = tick
            req.slot = slot
            placed.append((slot, req))
        self._m_admitted.inc(len(placed))
        return placed

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def __len__(self) -> int:
        return len(self._queue)
