"""End-to-end training driver: data -> microbatched pjit step -> checkpoints,
with fault containment, straggler monitoring, and elastic DP re-sharding.

On this container it runs REAL small-scale training (CPU, 1 device) — the
quickstart trains a ~10M model to visibly decreasing loss; on a pod the same
driver runs the production mesh (mesh_kind=single/multi).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
        --steps 50 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.data import DataConfig, SyntheticLM, prefix_embeds_stub
from repro.launch.fault_tolerance import (
    FailureInjector,
    RunGuard,
    StragglerMonitor,
    heartbeat_file,
)
from repro.launch.mesh import make_production_mesh, make_driver_mesh, use_mesh
from repro.launch.steps import build_train_step
from repro.models import init_params
from repro.optim import init_state


def make_mesh(kind: str):
    return make_driver_mesh(kind)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--mesh", default="none", choices=["none", "single", "multi"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--dp-size", type=int, default=1,
                    help="data shards for the (elastic) host pipeline")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ag"])
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rcfg = RunConfig(model=cfg, seq_len=args.seq_len,
                     global_batch=args.global_batch, mode="train",
                     microbatch=args.microbatch, learning_rate=args.lr,
                     warmup_steps=max(5, args.steps // 10),
                     grad_compression=args.grad_compression)
    mesh = make_mesh(args.mesh)

    with use_mesh(mesh):
        step_fn, shapes, shards = build_train_step(mesh, cfg, rcfg)
        params = init_params(jax.random.PRNGKey(0), cfg,
                             tp=mesh.shape["model"])
        params = jax.device_put(params, shards["params"])
        opt_state = jax.device_put(init_state(params), shards["opt_state"])

        prefix_n = cfg.num_prefix_embeds
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=args.seq_len - prefix_n,
                                      global_batch=args.global_batch))

        mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        start = 0
        if mgr is not None:
            restored, rstep = mgr.restore({"params": params, "opt": opt_state})
            if restored is not None:
                params = jax.device_put(restored["params"], shards["params"])
                opt_state = jax.device_put(restored["opt"], shards["opt_state"])
                start = rstep
                print(f"[restore] resumed from step {start}", flush=True)

        def restore_fn() -> int:
            nonlocal params, opt_state
            if mgr is None:
                return 0
            mgr.wait()
            restored, rstep = mgr.restore({"params": params, "opt": opt_state})
            if restored is None:
                return 0
            params = jax.device_put(restored["params"], shards["params"])
            opt_state = jax.device_put(restored["opt"], shards["opt_state"])
            return rstep

        injector = FailureInjector()
        monitor = StragglerMonitor()
        guard = RunGuard(restore_fn)
        losses = []

        step = start
        while step < args.steps:
            t0 = time.time()
            captured = {}

            def one_step(step=step):
                nonlocal params, opt_state
                injector.maybe_fail(step)
                toks, tgts = data.batch(step, shard=0,
                                        num_shards=1)  # host feed; device
                # sharding comes from in_shardings
                pre = prefix_embeds_stub(cfg, args.global_batch, seed=step)
                if pre is None:
                    pre = np.zeros((args.global_batch, 0, cfg.d_model),
                                   np.float32)
                params, opt_state, metrics = step_fn(
                    params, opt_state, jnp.asarray(toks), jnp.asarray(tgts),
                    jnp.asarray(pre), jnp.int32(step))
                captured.update(jax.tree.map(float, metrics))

            nxt = guard.run(step, one_step)
            if nxt <= step:  # restored backwards
                step = nxt
                continue
            dt = time.time() - t0
            monitor.observe(step, dt)
            losses.append(captured.get("loss", float("nan")))
            if step % args.log_every == 0:
                print(f"step {step:5d}  loss {captured.get('loss', -1):.4f}  "
                      f"gnorm {captured.get('grad_norm', -1):.3f}  "
                      f"lr {captured.get('lr', -1):.2e}  {dt:.2f}s", flush=True)
            if mgr is not None and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, {"params": params, "opt": opt_state})
            if args.ckpt_dir:
                heartbeat_file(f"{args.ckpt_dir}/heartbeat", step)
            step = nxt

        if mgr is not None:
            mgr.save(args.steps, {"params": params, "opt": opt_state},
                     blocking=True)
            mgr.wait()
        if monitor.straggles:
            print(f"[straggler] slow steps: {monitor.straggles}")
        print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
              f"median step {monitor.median:.2f}s")
        return losses


if __name__ == "__main__":
    main()
