"""Jitted step functions: train_step (microbatched grad accumulation),
prefill_step, serve_step (decode) — with full production shardings.

This module is mesh-parametric: given a mesh + RunConfig it returns AOT-
lowerable jitted callables with explicit in/out shardings. The dry-run
lowers exactly these steps; the train/serve drivers execute them.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.models import (
    ParallelCtx,
    decode_step,
    forward_seq,
    init_params,
    make_cache,
    model_dims,
)
from repro.models.common import quantize_params
from repro.optim import (
    AdamWConfig,
    apply_updates,
    compressed_psum,
    init_state,
    warmup_cosine,
)
from . import sharding as SH
from .mesh import compat_shard_map, dp_axes, tp_axis


# ---------------------------------------------------------------------------
# Context / helpers
# ---------------------------------------------------------------------------
def make_ctx(mesh, mode: str) -> ParallelCtx:
    return ParallelCtx(
        mesh=mesh,
        dp_axes=dp_axes(mesh),
        tp_axis=tp_axis(mesh),
        seq_shard_cache=(mode == "decode"),
    )


def batch_dp(mesh, global_batch: int):
    """The dp axes actually usable for this batch size (None if B too small)."""
    axes = dp_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and global_batch % n == 0:
        return axes
    # try data-only (drop pod)
    if "data" in axes and global_batch % mesh.shape["data"] == 0:
        return ("data",)
    return None


def _loss_fn(params, tokens, targets, cfg, rcfg: RunConfig, ctx, prefix,
             dims, dtype=jnp.bfloat16):
    logits, aux, _ = forward_seq(
        params, tokens, cfg, tp=ctx.tp if ctx else 1,
        ctx=ctx, remat=rcfg.remat, block_kv=rcfg.attn_block_kv,
        prefix_embeds=prefix, dtype=dtype)
    logits = logits[:, -targets.shape[1]:]  # skip prefix positions
    ls = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(ls, targets[..., None], axis=-1)[..., 0]
    loss = nll.mean()
    return loss + 0.01 * aux, loss


# ---------------------------------------------------------------------------
# TRAIN
# ---------------------------------------------------------------------------
def build_train_step(mesh, cfg: ModelConfig, rcfg: RunConfig):
    """Returns (step_fn, in_shardings, out_shardings, arg_shapes).

    step_fn(params, opt_state, tokens, targets, step) -> (params, opt_state,
    metrics). Gradient accumulation over microbatches via lax.scan; the
    DP/FSDP reductions are XLA-inserted from the shardings, except with
    grad_compression='int8_ag' where the cross-pod reduction is explicit
    (shard_map) int8-compressed.
    """
    ctx = make_ctx(mesh, "train")
    dims = model_dims(cfg, ctx.tp)
    B, S = rcfg.global_batch, rcfg.seq_len
    dp = batch_dp(mesh, B)
    dp_n = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    micro = rcfg.microbatch or dp_n  # default: 1 sample per dp shard
    assert B % micro == 0
    n_micro = B // micro
    adamw = AdamWConfig(grad_clip=rcfg.grad_clip)

    prefix_n = cfg.num_prefix_embeds
    S_tok = S - prefix_n  # frontend stub occupies prefix positions

    compress = (rcfg.grad_compression == "int8_ag" and dp is not None
                and "pod" in dp)

    def accum_grads(p_bf16, tok_m, tgt_m, pre_m):
        """Microbatch-accumulated grads (f32) + mean loss."""
        def micro_fn(acc, xs):
            tok, tgt, pre = xs
            (l, _), g = jax.value_and_grad(
                lambda p: _loss_fn(p, tok, tgt, cfg, rcfg, ctx, pre, dims),
                has_aux=True)(p_bf16)
            acc_g, acc_l = acc
            return (jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 acc_g, g), acc_l + l), None

        g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p_bf16)
        nm = tok_m.shape[0]
        (grads, loss_sum), _ = jax.lax.scan(micro_fn, (g0, jnp.float32(0)),
                                            (tok_m, tgt_m, pre_m))
        return (jax.tree.map(lambda g: g / nm, grads), loss_sum / nm)

    def accum_grads_podwise(p_bf16, tok_m, tgt_m, pre_m):
        """Pod axis manual: local grads, then an EXPLICIT int8-compressed
        cross-pod all-reduce (the all-gather half rides int8)."""
        npod = mesh.shape["pod"]

        def inner(p, tok, tgt, pre):
            g, l = accum_grads(p, tok, tgt, pre)
            g = compressed_psum(jax.tree.map(lambda x: x / npod, g), ("pod",))
            return g, jax.lax.pmean(l, "pod")

        p_specs = jax.tree.map(lambda _: P(), p_bf16)
        g_specs = jax.tree.map(lambda _: P(), p_bf16)
        data_spec = P(None, "pod", None)
        pre_spec = P(None, "pod", None, None)
        f = compat_shard_map(
            inner, mesh, {"pod"},
            in_specs=(p_specs, data_spec, data_spec, pre_spec),
            out_specs=(g_specs, P()))
        return f(p_bf16, tok_m, tgt_m, pre_m)

    def step_fn(params, opt_state, tokens, targets, prefix, step):
        p_bf16 = jax.tree.map(
            lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 and x.ndim >= 2 else x,
            params)
        tok_m = tokens.reshape(n_micro, micro, S_tok)
        tgt_m = targets.reshape(n_micro, micro, S_tok)
        pre_m = prefix.reshape(n_micro, micro, prefix_n, cfg.d_model)
        if dp:
            shard = NamedSharding(mesh, P(None, dp, None))
            tok_m = jax.lax.with_sharding_constraint(tok_m, shard)
            tgt_m = jax.lax.with_sharding_constraint(tgt_m, shard)
            pre_m = jax.lax.with_sharding_constraint(
                pre_m, NamedSharding(mesh, P(None, dp, None, None)))
        if compress:
            grads, loss = accum_grads_podwise(p_bf16, tok_m, tgt_m, pre_m)
        else:
            grads, loss = accum_grads(p_bf16, tok_m, tgt_m, pre_m)

        lr = warmup_cosine(step, rcfg.learning_rate, rcfg.warmup_steps, 10_000)
        params, opt_state, om = apply_updates(params, grads, opt_state, lr, adamw)
        metrics = {"loss": loss, "lr": lr, **om}
        return params, opt_state, metrics

    # --- shardings
    pshape = jax.eval_shape(
        lambda k: init_params(k, cfg, tp=ctx.tp), jax.random.PRNGKey(0))
    p_shard = SH.params_shardings(pshape, mesh, fsdp=rcfg.fsdp, moe="tp")
    o_shard = {"m": p_shard, "v": p_shard,
               "step": NamedSharding(mesh, P())}
    tok_shard = NamedSharding(mesh, P(dp, None))
    pre_shard = NamedSharding(mesh, P(dp, None, None))
    scalar = NamedSharding(mesh, P())
    # host-fed data args stay auto-sharded at the jit boundary (constraints
    # inside pin them); the dry-run's abstract args carry shardings instead.
    in_shardings = (p_shard, o_shard, None, None, None, None)
    out_shardings = (p_shard, o_shard,
                     jax.tree.map(lambda _: scalar,
                                  {"loss": 0, "lr": 0, "grad_norm": 0}))
    arg_shapes = dict(
        params=pshape,
        opt_state=jax.eval_shape(init_state, pshape),
        tokens=jax.ShapeDtypeStruct((B, S_tok), jnp.int32, sharding=tok_shard),
        targets=jax.ShapeDtypeStruct((B, S_tok), jnp.int32, sharding=tok_shard),
        prefix=jax.ShapeDtypeStruct((B, prefix_n, cfg.d_model), jnp.float32,
                                    sharding=pre_shard),
        step=jax.ShapeDtypeStruct((), jnp.int32, sharding=scalar),
    )
    jitted = jax.jit(step_fn, in_shardings=in_shardings,
                     out_shardings=out_shardings,
                     donate_argnums=(0, 1))
    return jitted, arg_shapes, dict(params=p_shard, opt_state=o_shard,
                                    tokens=tok_shard, targets=tok_shard,
                                    prefix=pre_shard, step=scalar)


# ---------------------------------------------------------------------------
# SERVE: prefill + decode
# ---------------------------------------------------------------------------
def quantized_param_shapes(cfg: ModelConfig, rcfg: RunConfig, tp: int):
    """Abstract shapes of the serving params (quantized per policy)."""
    def build(k):
        p = init_params(k, cfg, tp=tp)
        p = jax.tree.map(lambda x: x.astype(jnp.bfloat16)
                         if x.ndim >= 2 else x, p)
        if rcfg.quantized:
            p = quantize_params(p, rcfg.quant)
        return p
    return jax.eval_shape(build, jax.random.PRNGKey(0))


def build_prefill_step(mesh, cfg: ModelConfig, rcfg: RunConfig):
    ctx = make_ctx(mesh, "prefill")
    dims = model_dims(cfg, ctx.tp)
    B, S = rcfg.global_batch, rcfg.seq_len
    dp = batch_dp(mesh, B)
    prefix_n = cfg.num_prefix_embeds
    S_tok = S - prefix_n
    policy = rcfg.quant if rcfg.quantized else None

    def prefill_fn(params, tokens, prefix):
        logits, _, cache = forward_seq(
            params, tokens, cfg, tp=ctx.tp, policy=policy, ctx=ctx,
            remat=False, block_kv=rcfg.attn_block_kv,
            prefix_embeds=prefix if prefix_n else None,
            want_cache=True, dtype=jnp.bfloat16)
        return logits[:, -1], cache

    pshape = quantized_param_shapes(cfg, rcfg, ctx.tp)
    p_shard = SH.params_shardings(pshape, mesh, fsdp=False)
    tok_shard = NamedSharding(mesh, P(dp, None))
    pre_shard = NamedSharding(mesh, P(dp, None, None))
    cache_shape = jax.eval_shape(
        lambda: make_cache(cfg, B, S, tp=ctx.tp, dtype=jnp.bfloat16))
    c_shard = SH.cache_shardings(cache_shape, mesh, dp=dp, seq_shard=True)
    out_shardings = (NamedSharding(mesh, P(dp, "model")), c_shard)
    jitted = jax.jit(prefill_fn,
                     in_shardings=(p_shard, None, None),
                     out_shardings=out_shardings)
    arg_shapes = dict(
        params=pshape,
        tokens=jax.ShapeDtypeStruct((B, S_tok), jnp.int32, sharding=tok_shard),
        prefix=jax.ShapeDtypeStruct((B, prefix_n, cfg.d_model), jnp.float32,
                                    sharding=pre_shard),
    )
    return jitted, arg_shapes, dict(params=p_shard, tokens=tok_shard,
                                    prefix=pre_shard)


def build_serve_step(mesh, cfg: ModelConfig, rcfg: RunConfig):
    """One decode step: (params, token [B], cache, pos) -> (logits, cache)."""
    ctx = make_ctx(mesh, "decode")
    B, S = rcfg.global_batch, rcfg.seq_len
    dp = batch_dp(mesh, B)
    policy = rcfg.quant if rcfg.quantized else None

    def serve_fn(params, token, cache, pos):
        return decode_step(params, token, cache, pos, cfg, tp=ctx.tp,
                           policy=policy, ctx=ctx, dtype=jnp.bfloat16)

    pshape = quantized_param_shapes(cfg, rcfg, ctx.tp)
    p_shard = SH.params_shardings(pshape, mesh, fsdp=False)
    cache_shape = jax.eval_shape(
        lambda: make_cache(cfg, B, S, tp=ctx.tp, dtype=jnp.bfloat16))
    c_shard = SH.cache_shardings(cache_shape, mesh, dp=dp, seq_shard=True)
    tok_shard = NamedSharding(mesh, P(dp))
    scalar = NamedSharding(mesh, P())
    jitted = jax.jit(
        serve_fn,
        in_shardings=(p_shard, None, c_shard, None),
        out_shardings=(NamedSharding(mesh, P(dp, "model")), c_shard),
        donate_argnums=(2,),
    )
    arg_shapes = dict(
        params=pshape,
        token=jax.ShapeDtypeStruct((B,), jnp.int32, sharding=tok_shard),
        cache=cache_shape,
        pos=jax.ShapeDtypeStruct((), jnp.int32,
                                 sharding=NamedSharding(mesh, P())),
    )
    return jitted, arg_shapes, dict(params=p_shard, token=tok_shard,
                                    cache=c_shard, pos=scalar)


def build_engine_step(mesh, cfg: ModelConfig, rcfg: RunConfig,
                      cache_cfg=None, chunk: int = 1,
                      sampling: bool = False, speculate_k: int = 0):
    """Slot-masked decode step for the continuous-batching engine.

    One tick serves every slot of the fixed-capacity KV cache at its OWN
    position: ``pos`` is [B] int32 per-slot insert positions (negative =
    idle slot; its cache write is suppressed and its output is garbage the
    engine ignores). Slots still consuming their prompt ride the same step
    as decoding slots and the engine discards their logits until the last
    prompt token.

    RAGGED MULTI-TOKEN STEP (``chunk`` = C > 1): every slot contributes a
    variable-length block of up to C tokens per tick — prefilling slots a
    prompt chunk, decoding slots 1, idle slots 0 — still as ONE jitted
    program. ``token`` becomes [B, C], ``pos`` [B] holds each slot's START
    position, and an extra [B] int32 ``nvalid`` arg (after pos) carries the
    per-slot valid length; logits are taken in-step at each slot's last
    valid token. Pure-attention families only (`check_chunked_support`).

    SAMPLING (``sampling=True``): the step's epilogue becomes the
    per-slot stochastic draw of `repro.launch.sampling` — temperature /
    top-k / top-p transforms and the categorical draw run ON DEVICE from
    per-slot folded PRNG keys, and termination (stop-token hit or length
    cap) is decided in-step. The step then takes one extra pytree arg
    ``sampling`` (see `sampling.slot_batch`: per-slot key/ngen/
    temperature/top_k/top_p/max_tokens/stop_ids rows) after the last
    positional input, and returns an extra [B] bool ``done`` flag. An
    all-greedy batch lowers to the exact argmax path via lax.cond, so
    greedy ticks are bit-identical to (and as cheap as) the
    ``sampling=False`` step. Per tick only [B] int32 tokens + [B] bools
    cross back to the host.

    Without sampling, greedy argmax runs on-device so each tick moves only
    [B] int32s back to the host scheduler.

    step(params, token [B] | [B, C], pos [B][, nvalid [B]], cache
         [, block_tables [B, MP]][, embeds, embed_mask][, sampling])
        -> (next_token [B][, done [B]], cache)

    The embeds override exists only when the config has a modality frontend
    (``num_prefix_embeds > 0``): prefix embeddings stream through the same
    step during prefill instead of a separate prefill program ([B, D] +
    [B] mask in the one-token step, [B, C, D] + [B, C] in the ragged step).

    SPECULATIVE DECODING (``speculate_k`` = K > 0, requires sampling and
    chunk >= K+1): a slot's chunk may end in up to K DRAFT tokens (an
    extra [B] int32 ``ndraft`` arg after nvalid carries the per-slot draft
    count; 0 = plain decode/prefill round, identical to before). The step
    scores all fed positions in the one ragged pass, runs the
    accept/resample rule of `repro.launch.speculative.verify_tokens` on
    device, zero-scatters the REJECTED suffix out of every cache leaf
    in-program (`speculative.truncate_cache` — so the cache the step hands
    back never contains rejected entries), and returns the whole emission:

        step(...) -> (out_tokens [B, K+1], n_emit [B], accepted [B],
                      done [B], cache)

    ``out_tokens[b, :n_emit[b]]`` are slot b's emitted tokens this round
    (accepted drafts + the bonus/corrective draw, truncated at an in-step
    stop/length hit); ``accepted`` is the raw accepted-draft count (the
    accept-rate statistic). Temperature-0 rows emit bit-exactly the
    non-speculative greedy stream; the host engine rewinds its feed
    position to ``pos + 1 + accepted``.

    With a paged ``cache_cfg`` (see `repro.cache.CacheConfig`), the cache
    pytree holds PAGE POOLS and the step takes the per-slot block tables as
    an extra [B, max_pages_per_seq] int32 arg after the cache. A block-table
    row may MIX pages: a shared (read-only, prefix-cached) page prefix
    followed by the slot's private insert-target pages. The step needs no
    distinction — reads walk the whole row, and writes only ever land in
    private pages because the engine starts each slot's positions at its
    cached length (asserted host-side per tick). The slot-masking contract
    is unchanged.

    TENSOR-PARALLEL (``mesh`` with model-axis size tp > 1): the paged step
    runs sharded with BIT-IDENTICAL streams to tp=1. Weight planes are
    placed by the serving layout (`sharding.params_shardings` with
    ``serve_n_shard=True`` — every linear N-sharded, so no contraction is
    ever split across devices), the page pools are HEAD-SHARDED over the
    model axis (`sharding.pool_shardings`; insert/truncate/attend run on
    local head slices under shard_map — pages never cross the mesh), the
    residual stream and the logits are pinned replicated so the f32
    norm/softmax reductions stay device-complete, and block tables /
    positions / per-slot lengths replicate. The host-side scheduler,
    `PageAllocator` and prefix-cache index are device-count-agnostic:
    page ids are head-dimension-free.

    A CONTIGUOUS ``cache_cfg`` threads through too: its ``impl`` field
    selects the attention lowering for the GQA/MLA decode cores ("ref" =
    the plain-XLA flash decode, default; "pallas"/"pallas_interpret" =
    the fused template of `kernels.attention_template`). Every cache mode
    x family x chunk combination therefore compiles through the same
    template module; impl is part of `engine_step_signature`.
    """
    ctx = make_ctx(mesh, "decode")
    paged = cache_cfg is not None and cache_cfg.paged
    if ctx.tp == 1 or paged:  # trivial model axis / pooled pages: no
        ctx = dataclasses.replace(ctx, seq_shard_cache=False)
    B, S = rcfg.global_batch, rcfg.seq_len
    dp = batch_dp(mesh, B)
    policy = rcfg.quant if rcfg.quantized else None
    has_prefix = cfg.num_prefix_embeds > 0
    chunked = chunk > 1
    if chunked:
        from repro.models import check_chunked_support
        check_chunked_support(cfg)
    spec = speculate_k > 0
    if spec and not sampling:
        raise ValueError("speculate_k requires sampling=True (the verify "
                         "rule subsumes the sampling epilogue)")
    if spec and chunk < speculate_k + 1:
        raise ValueError(
            f"speculate_k={speculate_k} needs chunk >= {speculate_k + 1} "
            f"(one fed token + k drafts per slot), got chunk={chunk}")

    def _rep_logits(logits):
        """Pin logits replicated over the model axis before the epilogue:
        sampling's softmax/cumsum (and verify's accept rule) reduce over
        the vocab dim — a model-sharded vocab would split those f32
        reductions and break bit-identity with tp=1. At tp=1: no-op."""
        if ctx.tp <= 1:
            return logits
        spec_ = P(*((dp,) + (None,) * (logits.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            logits, NamedSharding(mesh, spec_))

    def core(params, token, pos, cache, block_tables=None, embeds=None,
             embed_mask=None, nvalid=None, samp=None, ndraft=None):
        if spec:
            from repro.launch.speculative import truncate_cache, verify_tokens
            logits, cache = decode_step(
                params, token, cache, pos, cfg, tp=ctx.tp, policy=policy,
                ctx=ctx, dtype=jnp.bfloat16, embeds=embeds,
                embed_mask=embed_mask, block_tables=block_tables,
                cache_cfg=cache_cfg, nvalid=nvalid, ndraft=ndraft,
                n_logits=speculate_k + 1)
            logits = _rep_logits(logits)
            out, n_emit, accepted, done = verify_tokens(
                logits, token, nvalid, ndraft, samp, speculate_k)
            # un-insert the rejected suffix IN-PROGRAM: positions
            # pos+1+accepted .. pos+ndraft revert to pool-initial zeros,
            # so the returned cache never holds rejected entries and the
            # host's position rewind is all the rollback there is
            cache = truncate_cache(
                cache, pos + 1 + accepted,
                jnp.maximum(ndraft - accepted, 0), speculate_k,
                cache_cfg=cache_cfg, block_tables=block_tables)
            return out, n_emit, accepted, done, cache
        logits, cache = decode_step(
            params, token, cache, pos, cfg, tp=ctx.tp, policy=policy,
            ctx=ctx, dtype=jnp.bfloat16, embeds=embeds, embed_mask=embed_mask,
            block_tables=block_tables, cache_cfg=cache_cfg, nvalid=nvalid)
        logits = _rep_logits(logits)
        if samp is not None:
            from repro.launch.sampling import sample_tokens
            next_token, done = sample_tokens(logits, samp)
            return next_token, done, cache
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    pshape = quantized_param_shapes(cfg, rcfg, ctx.tp)
    p_shard = SH.params_shardings(pshape, mesh, fsdp=False,
                                  serve_n_shard=True)
    cache_shape = jax.eval_shape(
        lambda: make_cache(cfg, B, S, tp=ctx.tp, dtype=jnp.bfloat16,
                           cache_cfg=cache_cfg))
    if paged:
        # kv heads over the model axis (replicated fallback when they
        # don't divide it) — must agree with models.transformer's
        # pool_head_sharded/shard_map wrap, and it does: same rule
        c_shard = SH.pool_shardings(cache_shape, mesh)
    else:
        c_shard = SH.cache_shardings(cache_shape, mesh, dp=dp, seq_shard=True)
    tok_shard = NamedSharding(mesh, P(dp))

    # one signature for every (chunked, paged, prefix) combination: the
    # ordered arg-name list drives the closure, the shardings tuple AND the
    # donated cache index, so an optional input added here can never be
    # mis-threaded in one branch only
    arg_names = (["token", "pos"] + (["nvalid"] if chunked else [])
                 + (["ndraft"] if spec else [])
                 + ["cache"] + (["block_tables"] if paged else [])
                 + (["embeds", "embed_mask"] if has_prefix else [])
                 + (["sampling"] if sampling else []))

    def engine_fn(params, *args):
        kw = dict(zip(arg_names, args))
        return core(params, kw["token"], kw["pos"], kw["cache"],
                    kw.get("block_tables"), kw.get("embeds"),
                    kw.get("embed_mask"), kw.get("nvalid"),
                    kw.get("sampling"), kw.get("ndraft"))

    in_shardings = (p_shard,) + tuple(
        c_shard if n == "cache" else None for n in arg_names)
    tok2_shard = NamedSharding(mesh, P(dp, None))
    if spec:
        out_shardings = (tok2_shard, tok_shard, tok_shard, tok_shard, c_shard)
    elif sampling:
        out_shardings = (tok_shard, tok_shard, c_shard)
    else:
        out_shardings = (tok_shard, c_shard)
    jitted = jax.jit(engine_fn, in_shardings=in_shardings,
                     out_shardings=out_shardings,
                     donate_argnums=(1 + arg_names.index("cache"),))
    # arg_shapes preserves the jitted signature's POSITIONAL order — the
    # dry-run lowers via `jitted.lower(*arg_shapes.values())`
    tok_shape = (B, chunk) if chunked else (B,)
    arg_shapes = dict(
        params=pshape,
        token=jax.ShapeDtypeStruct(tok_shape, jnp.int32, sharding=tok_shard),
        pos=jax.ShapeDtypeStruct((B,), jnp.int32, sharding=tok_shard),
    )
    if chunked:
        arg_shapes["nvalid"] = jax.ShapeDtypeStruct((B,), jnp.int32,
                                                    sharding=tok_shard)
    if spec:
        arg_shapes["ndraft"] = jax.ShapeDtypeStruct((B,), jnp.int32,
                                                    sharding=tok_shard)
    arg_shapes["cache"] = cache_shape
    if paged:
        arg_shapes["block_tables"] = jax.ShapeDtypeStruct(
            (B, cache_cfg.max_pages_per_seq), jnp.int32)
    if has_prefix:
        emb_shape = (B, chunk, cfg.d_model) if chunked else (B, cfg.d_model)
        msk_shape = (B, chunk) if chunked else (B,)
        arg_shapes["embeds"] = jax.ShapeDtypeStruct(emb_shape, jnp.float32)
        arg_shapes["embed_mask"] = jax.ShapeDtypeStruct(msk_shape, jnp.bool_)
    if sampling:
        from repro.launch.sampling import batch_shapes
        arg_shapes["sampling"] = batch_shapes(B)
    shardings = dict(params=p_shard, token=tok_shard, pos=tok_shard,
                     cache=c_shard)
    if chunked:
        shardings["nvalid"] = tok_shard
    if spec:
        shardings["ndraft"] = tok_shard
    return jitted, arg_shapes, shardings


def engine_step_signature(cfg: ModelConfig, rcfg: RunConfig, cache_cfg=None,
                          chunk: int = 1, speculate_k: int = 0, mesh=None):
    """Canonical identity of one jitted engine-step program — the key the
    obs subsystem attributes per-tick cost under (`obs.cost`) and the
    label set exported on ``serve_step_signature_info``. Two engines with
    equal signatures compile the same step: cache mode x attention impl x
    chunk x speculate_k x weight scheme x slot count x mesh shape. ``impl``
    is the attention lowering ("ref" = plain-XLA flash decode, "pallas"/
    "pallas_interpret" = the fused template of
    `kernels.attention_template`) — it now applies to contiguous caches
    too, so it is part of the compiled program's identity. ``tp`` is the
    model-axis size of the serving mesh: a sharded step is a different
    program (per-device weight/KV residency — see `obs.cost`'s per-device
    floors) even though its token streams are bit-identical."""
    return dict(
        arch=cfg.name,
        scheme=rcfg.quant.scheme if rcfg.quantized else "fp16",
        cache=cache_cfg.kind if cache_cfg is not None else "contiguous",
        kv_scheme=(cache_cfg.kv_scheme
                   if cache_cfg is not None and cache_cfg.quantized else "bf16"),
        impl=cache_cfg.impl if cache_cfg is not None else "ref",
        slots=rcfg.global_batch,
        chunk=chunk,
        speculate_k=speculate_k,
        tp=(int(mesh.shape["model"])
            if mesh is not None and "model" in mesh.axis_names else 1),
    )


def build_step(mesh, cfg: ModelConfig, rcfg: RunConfig):
    if rcfg.mode == "train":
        return build_train_step(mesh, cfg, rcfg)
    if rcfg.mode == "prefill":
        return build_prefill_step(mesh, cfg, rcfg)
    if rcfg.mode == "decode":
        return build_serve_step(mesh, cfg, rcfg)
    raise ValueError(rcfg.mode)
