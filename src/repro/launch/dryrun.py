import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract roofline inputs from the compiled artifact.

MUST be run as its own process (`python -m repro.launch.dryrun ...`): the
first two lines above pin 512 placeholder host devices before any jax
import, which is process-global.

Per cell it records:
  * memory_analysis()  — per-device bytes (proves the config fits HBM)
  * cost_analysis()    — HLO FLOPs / bytes accessed
  * collective operand bytes by kind, parsed from the post-SPMD HLO text
and writes experiments/dryrun/<mesh>/<arch>__<shape>.json.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.analysis.hlo import collective_bytes, hlo_op_histogram  # noqa: E402
from repro.launch.mesh import make_production_mesh, use_mesh  # noqa: E402
from repro.launch.specs import all_cells, make_run_config  # noqa: E402
from repro.launch.steps import build_step  # noqa: E402


def run_cell(arch: str, shape: str, *, multi_pod: bool, out_dir: str,
             rc_overrides=None, tag: str = "") -> dict:
    mesh_name = "pod512" if multi_pod else "pod256"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rcfg = make_run_config(arch, shape, **(rc_overrides or {}))
    with use_mesh(mesh):
        jitted, arg_shapes, _shardings = build_step(mesh, rcfg.model, rcfg)
        lowered = jitted.lower(*arg_shapes.values())
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo_text = compiled.as_text()

    coll = collective_bytes(hlo_text)
    from repro.analysis.hlo_cost import module_cost
    parsed = module_cost(hlo_text)
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "mode": rcfg.mode,
        "quant": rcfg.quant.scheme if rcfg.quant else "bf16",
        "quant_impl": rcfg.quant.impl if rcfg.quant else None,
        "devices": int(len(mesh.devices.flat)),
        "flops_per_device": float(cost.get("flops", -1.0)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", -1.0)),
        # trip-count-aware parsed totals (XLA cost_analysis counts while
        # bodies once; these multiply through the loop nest — see
        # analysis/hlo_cost.py):
        "parsed_flops": parsed.flops,
        "parsed_hbm_bytes": parsed.hbm_bytes,
        "parsed_collectives": dict(parsed.collectives),
        "parsed_traffic": dict(parsed.traffic),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", -1)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", -1)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", -1)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", -1)),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", -1)),
        },
        "collectives": coll,
        "hlo_ops": hlo_op_histogram(hlo_text),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    d = os.path.join(out_dir, mesh_name)
    os.makedirs(d, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    with open(os.path.join(d, f"{arch}__{shape}{suffix}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--quant-scheme", default=None)
    ap.add_argument("--quant-impl", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = all_cells()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
        if not cells:  # non-assigned archs (e.g. the paper's qwen2.5-7b)
            from repro.configs import get_config
            from repro.launch.specs import shapes_for
            cells = [(args.arch, s) for s in shapes_for(get_config(args.arch))]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    overrides = {}
    if args.quant_scheme or args.quant_impl:
        from repro.launch.specs import DEFAULT_SERVE_QUANT
        import dataclasses as dc
        q = DEFAULT_SERVE_QUANT
        if args.quant_scheme:
            q = dc.replace(q, scheme=args.quant_scheme)
        if args.quant_impl:
            q = dc.replace(q, impl=args.quant_impl)
        overrides["quant"] = q

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "pod512" if mp else "pod256"
            path = os.path.join(args.out, mesh_name,
                                f"{arch}__{shape}{('__' + args.tag) if args.tag else ''}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {arch} {shape} {mesh_name}")
                continue
            try:
                rec = run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                               rc_overrides=overrides, tag=args.tag)
                print(f"[ok] {arch:24s} {shape:12s} {mesh_name}  "
                      f"flops/dev={rec['flops_per_device']:.3e}  "
                      f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB  "
                      f"compile={rec['compile_s']}s", flush=True)
            except Exception as e:
                failures.append((arch, shape, mesh_name, repr(e)))
                print(f"[FAIL] {arch} {shape} {mesh_name}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f)
        raise SystemExit(1)
    print("\nAll dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
