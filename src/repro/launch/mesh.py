"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests see 1 device).

Also carries the small compat layer for older jax releases (0.4.x): no
``jax.sharding.AxisType`` and no ``jax.set_mesh`` — ``make_mesh``/``use_mesh``
below pick the right spelling so serving code runs on both.
"""

from __future__ import annotations

import contextlib

import jax

try:  # jax >= 0.5: explicit/auto axis types
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _mk_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(shape))
    return jax.make_mesh(shape, axes)


def use_mesh(mesh):
    """Context manager activating `mesh` as the ambient mesh.

    ``jax.set_mesh`` on current jax; the Mesh object's own context manager
    on older releases (sufficient for the Auto-axis style used here).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)


def compat_shard_map(f, mesh, axis_names, in_specs, out_specs):
    """`jax.shard_map` across the supported jax range.

    Current jax spells it ``jax.shard_map(..., axis_names=...,
    check_vma=...)``; 0.4.x has only ``jax.experimental.shard_map`` with
    ``check_rep``, where the region is manual over EVERY mesh axis (its
    partial-manual ``auto=`` mode lowers to a PartitionId op XLA's CPU
    SPMD partitioner rejects). Axes absent from the specs are then
    manually replicated — same math for every region in this tree (none
    runs collectives over its auto axes), at worst extra replication on
    0.4.x."""
    axis_names = set(axis_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, axis_names=axis_names,
                             in_specs=in_specs, out_specs=out_specs,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk_mesh(shape, axes)


def make_driver_mesh(kind: str = "none"):
    """Kind-dispatch mesh for the serve/train drivers: 'none' = 1x1 host mesh."""
    if kind == "none":
        return _mk_mesh((1, 1), ("data", "model"))
    return make_production_mesh(multi_pod=(kind == "multi"))


def make_serving_mesh(tp: int = 1):
    """(1, tp) mesh for tensor-parallel serving: one replica, `tp` model
    shards. Pass the result to ``ServeEngine(mesh=...)`` /
    ``build_engine_step`` — needs `tp` visible devices (on CPU, force them
    with XLA_FLAGS=--xla_force_host_platform_device_count=N before the
    first jax import)."""
    return _mk_mesh((1, tp), ("data", "model"))


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for multi-device unit tests (8 forced host devices)."""
    return _mk_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"
