"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests see 1 device).
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(shape))


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for multi-device unit tests (8 forced host devices)."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"
