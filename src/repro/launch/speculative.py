"""Speculative decoding through the ragged engine step.

A DRAFTER proposes up to k candidate tokens per decoding slot; the engine
feeds ``[last_token, d_1 .. d_k]`` through the SAME ragged multi-token step
chunked prefill uses (`launch.steps.build_engine_step(speculate_k=k)`), so
ONE pass of the AMS-quantized weights + KV pool scores every candidate.
The step returns target logits at all k+1 fed positions; this module's
`verify_tokens` then decides, on device, the longest accepted draft prefix
and the one extra token every round emits (the "bonus" draw when all
drafts are accepted, the corrective draw at the first rejection).

Acceptance rule (the standard rejection scheme, specialized to
DETERMINISTIC drafters — both built-in drafters propose point masses):

  * greedy rows (temperature == 0): draft j+1 is accepted iff it equals
    ``argmax`` of the target logits at position j; the emitted extra token
    is the argmax at the first mismatch (or after the last draft). The
    emitted stream is therefore BIT-IDENTICAL to non-speculative greedy
    decoding — speculation only changes how many tokens emerge per step,
    never which tokens.
  * sampled rows (temperature > 0): with a deterministic proposal q =
    delta(d_j), draft j is accepted with probability p_j(d_j) where p_j is
    the target distribution (temperature / top-k / top-p transforms of
    `launch.sampling`, applied to the logits at position j). On rejection
    the extra token is drawn from the residual ``norm(max(p_j - q, 0))``,
    which for a point-mass q is exactly p_j with d_j masked out and
    renormalized. A round where every draft is accepted draws the bonus
    token from p_k unmodified. Each emitted position therefore marginally
    follows the exact target distribution (`tests/test_speculative.py`
    pins this with a chi-square test).

PRNG discipline matches `launch.sampling`: the key for the decision at
stream index n is ``fold_in(request_key, n)`` — request id + token index,
never the slot, tick, or round shape — with the accept uniform and the
resample draw split off that key by a further fold. Seeded speculative
streams replay bit-identically across restarts, slot counts and chunk
settings (though not across drafters: different proposals consume the
acceptance uniforms differently at temperature > 0).

Termination (stop tokens / length cap, PR 5) is applied in-step per
EMITTED index: the round's emission is truncated at the first stop-token
hit or at the length cap, so a stop token can land mid-round.

Rollback of rejected KV entries happens in the same jitted program (see
`truncate_cache` here and `pool.paged_truncate`): rejected suffix
positions are zero-scattered back to the pool's initial state, so a later
re-insert at those positions is indistinguishable from a straight insert
(quantization at insert is deterministic). The engine then rewinds its
host-side feed position — never past the shared prefix-cache pages, which
speculation structurally cannot touch (drafting starts after the prompt).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.sampling import _masked_logits


# ---------------------------------------------------------------------------
# drafters (host-side proposal; both deterministic)
# ---------------------------------------------------------------------------
class Drafter:
    """Proposal interface: ``propose(history, k)`` returns up to k draft
    tokens (np.int32 [<=k]) continuing ``history`` (prompt + generated so
    far, [L] int32). Proposals must be DETERMINISTIC functions of the
    history — the rejection rule implemented here assumes point-mass
    proposals, and replay determinism of seeded streams depends on it."""

    name = "drafter"
    _m_proposed = None        # per-drafter proposal counter (bind_metrics)

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        raise NotImplementedError

    def bind_metrics(self, registry) -> None:
        """Attach an `obs.MetricsRegistry`: proposals are counted per
        drafter name, so mixed-drafter deployments stay attributable.
        The engine binds its registry at construction."""
        self._m_proposed = registry.counter(
            "spec_drafter_proposed_total",
            "draft tokens proposed, by drafter", ("drafter",)
        ).labels(drafter=self.name)

    def record_proposal(self, n: int) -> None:
        """Called by the engine for each accepted-into-the-step proposal
        block (no-op until bind_metrics)."""
        if self._m_proposed is not None:
            self._m_proposed.inc(n)


class NgramDrafter(Drafter):
    """Prompt-lookup decoding: match the longest trailing n-gram of the
    history against its earlier occurrences and propose the tokens that
    followed the MOST RECENT match. Free (no model call) and strong on
    repetitive continuations — retrieval prompts, code, and the looping
    tails greedy decoding produces."""

    name = "ngram"

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError(f"need 1 <= min_ngram <= max_ngram, got "
                             f"{min_ngram}..{max_ngram}")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        h = np.asarray(history, np.int32)
        L = h.shape[0]
        for n in range(min(self.max_ngram, L - 1), self.min_ngram - 1, -1):
            pattern = h[L - n:]
            # candidate start positions of earlier occurrences (the match
            # must END before the trailing n-gram starts)
            starts = np.arange(L - n)
            windows = np.lib.stride_tricks.sliding_window_view(h[:L - 1], n) \
                if L - 1 >= n else np.zeros((0, n), np.int32)
            hits = starts[:windows.shape[0]][
                np.all(windows == pattern[None, :], axis=1)]
            if hits.size:
                p = int(hits[-1])                    # most recent occurrence
                return h[p + n: p + n + k].copy()
        return np.zeros(0, np.int32)


class SelfDrafter(Drafter):
    """Early-exit self-drafting: greedy proposals from the FIRST
    ``draft_groups`` stacked layer groups of the serving model itself —
    the same (quantized) weights, embedding and head, just a truncated
    stack. Zero extra parameters; the draft forward reuses
    `models.forward_seq` over a fixed-capacity buffer (causal masking
    makes the padding inert), compiled once per engine.

    ``draft_groups=None`` keeps the full stack (an exact-oracle drafter,
    useful for tests and accept-rate ceilings)."""

    name = "self"

    def __init__(self, params, cfg, capacity: int, *,
                 draft_groups: Optional[int] = 1, tp: int = 1, policy=None):
        import dataclasses as _dc

        from repro.models import forward_seq
        from repro.models.transformer import layer_pattern

        pat = layer_pattern(cfg)
        n_groups = jax.tree.leaves(params["layers"])[0].shape[0]
        g = n_groups if draft_groups is None else draft_groups
        if not 1 <= g <= n_groups:
            raise ValueError(f"draft_groups must be in [1, {n_groups}], got {g}")
        self.draft_params = {
            "embed": params["embed"],
            "layers": jax.tree.map(lambda x: x[:g], params["layers"]),
            "final_norm": params["final_norm"],
            "lm_head": params["lm_head"],
        }
        # the truncated stack has g full pattern repeats and no tail
        self.draft_cfg = _dc.replace(cfg, num_layers=g * len(pat))
        self.capacity = capacity

        def fwd(p, tokens):
            logits, _, _ = forward_seq(p, tokens, self.draft_cfg, tp=tp,
                                       policy=policy, ctx=None, remat=False)
            return jnp.argmax(logits[0], axis=-1).astype(jnp.int32)  # [S]

        self._fwd = jax.jit(fwd)

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        h = np.asarray(history, np.int32)
        # keep the most recent context that leaves room for k drafts in the
        # fixed buffer (proposals from a truncated context are still valid
        # proposals — correctness lives in the verify step)
        h = h[max(0, h.shape[0] - (self.capacity - k)):]
        L = h.shape[0]
        buf = np.zeros(self.capacity, np.int32)
        buf[:L] = h
        out = []
        for j in range(k):
            nxt = int(np.asarray(self._fwd(self.draft_params,
                                           jnp.asarray(buf[None, :])))[L + j - 1])
            buf[L + j] = nxt
            out.append(nxt)
        return np.asarray(out, np.int32)


def make_drafter(name: str, *, params=None, cfg=None, capacity: int = 0,
                 tp: int = 1, policy=None) -> Drafter:
    """Engine-facing factory: ``"ngram"`` needs nothing; ``"self"`` binds
    the first stacked group of the engine's own params/config, and
    ``"self-full"`` the whole stack (the accept-rate ceiling: proposals
    are the target model's own greedy continuations, re-derived without
    the quantized KV pool)."""
    if name == "ngram":
        return NgramDrafter()
    if name in ("self", "self-full"):
        return SelfDrafter(params, cfg, capacity, tp=tp, policy=policy,
                           draft_groups=None if name == "self-full" else 1)
    raise ValueError(f"unknown drafter {name!r} "
                     "(expected 'ngram', 'self' or 'self-full')")


# ---------------------------------------------------------------------------
# on-device verify: accept / resample / terminate
# ---------------------------------------------------------------------------
def _row_greedy(logits, drafts, ndraft):
    """One slot, temperature 0: accepted = longest draft prefix matching
    the running argmax; candidate token at every position is the argmax."""
    cand = jnp.argmax(logits, axis=-1).astype(jnp.int32)          # [K+1]
    jj = jnp.arange(drafts.shape[0])
    ok = (drafts == cand[:-1]) & (jj < ndraft)
    acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32)))
    return acc.astype(jnp.int32), cand


def _row_sampled(logits, drafts, ndraft, key, ngen, temperature, top_k, top_p):
    """One slot, temperature > 0: rejection rule against the point-mass
    proposal. Position j's decisions use fold_in(key, ngen + j) — the same
    token-index key discipline as `sampling.sample_tokens` — with the
    accept uniform and the resample draw on distinct sub-folds."""
    K = drafts.shape[0]
    v = logits.shape[-1]
    safe_t = jnp.where(temperature > 0, temperature, 1.0)
    scaled = logits.astype(jnp.float32) / safe_t                  # [K+1, V]
    masked = jax.vmap(_masked_logits, in_axes=(0, None, None))(
        scaled, top_k, top_p)
    logp = jax.nn.log_softmax(masked, axis=-1)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        key, ngen + jnp.arange(K + 1))
    k_accept = jax.vmap(jax.random.fold_in, in_axes=(0, None))(keys, 1)
    k_draw = jax.vmap(jax.random.fold_in, in_axes=(0, None))(keys, 2)

    # accept draft j with probability p_j(d_j)
    p_d = jnp.exp(jnp.take_along_axis(logp[:K], drafts[:, None], axis=-1)[:, 0])
    u = jax.vmap(jax.random.uniform)(k_accept[:K])
    jj = jnp.arange(K)
    ok = (u < p_d) & (jj < ndraft)
    acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32)))

    # candidate at j < ndraft: residual draw = p_j with d_j masked out
    # (point-mass residual); at j >= ndraft: the unmodified bonus draw
    excl = jnp.where(jax.nn.one_hot(drafts, v, dtype=bool), -jnp.inf,
                     masked[:K])
    resampled = jax.vmap(jax.random.categorical)(k_draw[:K], excl)
    plain = jax.vmap(jax.random.categorical)(k_draw, masked)
    cand = jnp.concatenate([jnp.where(jj < ndraft, resampled, plain[:K]),
                            plain[K:]]).astype(jnp.int32)
    return acc.astype(jnp.int32), cand


def verify_tokens(logits, token, nvalid, ndraft, sampling, k_max: int):
    """The speculative step epilogue: accept drafts, emit, terminate.

    logits   [B, K+1, V]  target logits at the last ndraft+1 fed positions
                          (row j scores the token AFTER draft j; row 0 is
                          the position non-speculative decoding samples)
    token    [B, C]       the fed chunk; drafts sit at chunk indices
                          nvalid-ndraft .. nvalid-1
    nvalid   [B]          fed count per slot (1 + ndraft for spec rounds)
    ndraft   [B]          draft count per slot (0 = plain decode/prefill)
    sampling              the `slot_batch` pytree

    Returns (out_tokens [B, K+1], n_emit [B], accepted [B], done [B]):
    ``out_tokens[:, :n_emit]`` are the round's emitted tokens (accepted
    drafts then the bonus/corrective draw, truncated at the first in-step
    stop-token or length-cap hit); ``accepted`` is the accepted-draft
    count (before truncation — the accept-rate statistic). Slots with
    ndraft == 0 reduce exactly to `sampling.sample_tokens` semantics:
    one emitted token, same greedy argmax, same done rule.
    """
    B, C = token.shape
    dstart = nvalid - ndraft                                  # first draft idx
    didx = jnp.clip(dstart[:, None] + jnp.arange(k_max)[None, :], 0, C - 1)
    drafts = jnp.take_along_axis(token, didx, axis=1)         # [B, K]

    def all_greedy_fn(lg):
        return jax.vmap(_row_greedy)(lg, drafts, ndraft)

    def mixed_fn(lg):
        acc_s, cand_s = jax.vmap(_row_sampled)(
            lg, drafts, ndraft, sampling["key"], sampling["ngen"],
            sampling["temperature"], sampling["top_k"], sampling["top_p"])
        acc_g, cand_g = jax.vmap(_row_greedy)(lg, drafts, ndraft)
        sampled = sampling["temperature"] > 0.0
        return (jnp.where(sampled, acc_s, acc_g),
                jnp.where(sampled[:, None], cand_s, cand_g))

    all_greedy = jnp.all(sampling["temperature"] <= 0.0)
    acc, cand = jax.lax.cond(all_greedy, all_greedy_fn, mixed_fn,
                             logits.astype(jnp.float32))

    final = jnp.take_along_axis(cand, acc[:, None], axis=1)[:, 0]
    jj = jnp.arange(k_max + 1)[None, :]
    dpad = jnp.pad(drafts, ((0, 0), (0, 1)))
    out = jnp.where(jj < acc[:, None], dpad,
                    jnp.where(jj == acc[:, None], final[:, None], 0)
                    ).astype(jnp.int32)

    # in-step termination per EMITTED index: stop-token hit or length cap
    # truncates the round's emission (PR 5 semantics, generalized to k+1)
    stop_hit = jnp.any(out[:, :, None] == sampling["stop_ids"][:, None, :],
                       axis=-1)
    len_hit = sampling["ngen"][:, None] + jj + 1 >= \
        sampling["max_tokens"][:, None]
    end = (stop_hit | len_hit) & (jj <= acc[:, None])
    done = jnp.any(end, axis=1)
    n_emit = jnp.where(done, jnp.argmax(end, axis=1) + 1, acc + 1)
    return out, n_emit.astype(jnp.int32), acc, done


# ---------------------------------------------------------------------------
# in-step rollback: zero rejected suffix positions back to pool-initial state
# ---------------------------------------------------------------------------
def truncate_cache(cache, start, count, c_max: int, cache_cfg=None,
                   block_tables=None):
    """Un-insert ``count`` cache positions starting at ``start`` (per slot)
    from every KV leaf — paged pools via (page, offset) from the block
    table, contiguous caches via (slot, row). Zeroing restores the exact
    initial pool state, so rewind + re-insert ≡ straight insert bit-for-bit
    (pinned by tests/test_paged_cache.py). Runs inside the jitted engine
    step; slots with count == 0 are full no-ops via scatter mode='drop'.

    ``cache`` is the engine cache pytree ({"layers": {subN: pool-or-block
    stacked [G, ...]}, optional "tail"}); ``c_max`` bounds the per-slot
    rewind width (the step's speculate_k)."""
    paged = cache_cfg is not None and cache_cfg.paged
    start = jnp.asarray(start, jnp.int32)
    count = jnp.asarray(count, jnp.int32)
    if paged:
        from repro.cache import paged_truncate
        def f(pool):
            return paged_truncate(pool, start, count, block_tables,
                                  cache_cfg, c_max)
    else:
        from repro.models.attention import cache_truncate_chunk
        def f(block):
            return jax.tree.map(
                lambda leaf: cache_truncate_chunk(leaf, start, count, c_max),
                block)
    out = {"layers": {k: jax.vmap(f)(v) for k, v in cache["layers"].items()}}
    if "tail" in cache:
        out["tail"] = {k: f(v) for k, v in cache["tail"].items()}
    return out
