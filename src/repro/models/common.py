"""Shared model building blocks: norms, RoPE, quant-aware linears, padding.

Parameters are plain nested dicts of jnp arrays (pytrees). A "linear" is a
sub-dict: ``{'w': [K, N]}`` (+ optional ``'b': [N]``) in high precision, or —
after offline AMS-Quant PTQ — ``{'hi', 'lsb', 'scale'}`` packed planes
(+ optional ``'b'``). ``apply_linear`` dispatches on which keys are present,
so the same model code serves both the bf16 training path and the quantized
serving path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import get_scheme
from repro.core.packing import PackedWeight, make_layout
from repro.core.policy import QuantPolicy


def ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad_heads(h: int, tp: int) -> int:
    """Pad a head count so it shards evenly over `tp`-way tensor parallelism."""
    return ceil_to(h, tp)


# --------------------------------------------------------------------- init
def make_linear(key, K: int, N: int, bias: bool = False,
                dtype=jnp.float32, scale: float | None = None) -> Dict[str, Any]:
    s = scale if scale is not None else 1.0 / np.sqrt(K)
    p = {"w": (jax.random.normal(key, (K, N), jnp.float32) * s).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((N,), dtype)
    return p


def make_norm(d: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


# -------------------------------------------------------------------- apply
def apply_linear(p: Dict[str, Any], x: jnp.ndarray,
                 policy: Optional[QuantPolicy] = None) -> jnp.ndarray:
    """y = x @ W (+b); dispatches plain vs AMS-packed representation."""
    if "w" in p:
        y = x @ p["w"].astype(x.dtype)
    else:
        scheme = get_scheme(policy.scheme)
        lay = make_layout(scheme)
        K = x.shape[-1]
        N = p["scale"].shape[-1]
        pw = PackedWeight(p["hi"], p["lsb"], p["scale"], lay, K, N)
        impl = policy.impl
        if impl == "ref":
            from repro.kernels import ref
            w = (ref.dequant_full(pw, jnp.float32)).astype(x.dtype)
            y = x @ w
        elif impl == "fused_ref":
            from repro.kernels import ref
            lead = x.shape[:-1]
            y = ref.ams_matmul_blocked(x.reshape(-1, K), pw)
            y = y.reshape(*lead, N).astype(x.dtype)
        elif impl in ("pallas", "pallas_interpret"):
            from repro.kernels import ops
            y = ops.ams_matmul(x, pw, interpret=(impl == "pallas_interpret"))
            y = y.astype(x.dtype)
        else:
            raise ValueError(f"unknown quant impl {impl!r}")
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def materialize_weight(p: Dict[str, Any], K: int, dtype,
                       policy: Optional[QuantPolicy] = None) -> jnp.ndarray:
    """Return the [K, N] weight, dequantizing packed planes if needed.

    Used where the weight participates in non-matmul math (MLA absorbed
    einsums): the packed representation is still what lives in HBM."""
    if "w" in p:
        return p["w"].astype(dtype)
    from repro.kernels import ref
    scheme = get_scheme(policy.scheme)
    lay = make_layout(scheme)
    N = p["scale"].shape[-1]
    pw = PackedWeight(p["hi"], p["lsb"], p["scale"], lay, K, N)
    return ref.dequant_full(pw, jnp.float32).astype(dtype)


def rms_norm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * g.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------- RoPE
def rope_angles(positions: jnp.ndarray, dim: int, theta: float) -> jnp.ndarray:
    """[..., dim/2] angles for given integer positions."""
    inv = 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim))
    return positions.astype(jnp.float32)[..., None] * inv


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, hd]; positions: [B, S] (or [S])."""
    hd = x.shape[-1]
    ang = rope_angles(positions, hd, theta)  # [B, S, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :].astype(x.dtype)  # [B, S, 1, hd/2]
    sin = sin[..., None, :].astype(x.dtype)
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ----------------------------------------------------------- quantize tree
def quantize_params(params, policy: QuantPolicy, strategy: Optional[str] = None):
    """Offline PTQ pass: replace eligible {'w': [.., K, N]} linears by packed
    planes. Handles stacked leading dims (scan layers, MoE experts) via vmap.
    Biases/norms/small tensors stay in high precision.
    """
    from repro.core.ams import ams_quantize
    from repro.core.packing import pack

    scheme = get_scheme(policy.scheme)
    strategy = strategy or policy.strategy
    lay = make_layout(scheme)

    def quant_one(w2d):  # [K, N] -> dict of planes (padded K)
        K = w2d.shape[0]
        Kp = lay.padded_k(K)
        wp = jnp.pad(w2d.astype(jnp.float32), ((0, Kp - K), (0, 0)))
        codes, scale = ams_quantize(wp, scheme, strategy)
        pw = pack(codes, scale, scheme)
        return {"hi": pw.hi, "lsb": pw.lsb, "scale": pw.scale}

    def visit(path: str, node):
        if isinstance(node, dict) and "w" in node:
            w = node["w"]
            if w.ndim >= 2 and policy.wants(path, w.shape[-2:]):
                fn = quant_one
                for _ in range(w.ndim - 2):
                    fn = jax.vmap(fn)
                out = fn(w)
                if "b" in node:
                    out["b"] = node["b"]
                return out
            return node
        if isinstance(node, dict):
            return {k: visit(f"{path}/{k}", v) for k, v in node.items()}
        return node

    return visit("", params)


@dataclasses.dataclass(frozen=True)
class Dims:
    """Padded, mesh-aware derived dimensions for one model instance.

    Head layout is GROUP-MAJOR: q-head slot j belongs to kv group j // gp;
    the first `gt` slots of each group are real heads, the rest are padding
    (dead: masked after attention, zero grads through wo). When the padded
    q-head count doesn't divide by the true kv count (MHA archs on a 16-way
    TP mesh), kv heads are padded too — this keeps attention a pure grouped
    einsum with ZERO gather/expand materialization of K/V.

    NOTE: this permutes head order vs. the original checkpoints; a loader
    would apply the corresponding column permutation (documented in
    DESIGN.md).
    """

    tp: int
    H: int          # padded q-head count
    H_true: int
    kv: int         # padded kv-head count
    kv_true: int
    hd: int
    V: int          # padded vocab
    V_true: int

    @property
    def gp(self) -> int:  # q-head slots per kv group
        return self.H // self.kv

    @property
    def gt(self) -> int:  # real q heads per real kv group
        return self.H_true // self.kv_true

    @property
    def head_mask(self) -> jnp.ndarray:
        j = jnp.arange(self.H)
        return ((j // self.gp < self.kv_true)
                & (j % self.gp < self.gt)).astype(jnp.float32)

    @property
    def vocab_mask_bias(self) -> jnp.ndarray:
        """Additive -inf bias for padded vocab slots."""
        return jnp.where(jnp.arange(self.V) < self.V_true, 0.0, -1e9).astype(jnp.float32)


def model_dims(cfg, tp: int = 1, head_dim: Optional[int] = None) -> Dims:
    hd = head_dim if head_dim is not None else cfg.head_dim
    H_true = cfg.num_heads
    kv_true = max(1, cfg.num_kv_heads)
    Hp = pad_heads(H_true, tp)
    kv = kv_true if Hp % kv_true == 0 else Hp  # MHA-ish: pad kv alongside q
    return Dims(
        tp=tp,
        H=Hp,
        H_true=H_true,
        kv=kv,
        kv_true=kv_true,
        hd=hd,
        V=ceil_to(cfg.vocab_size, tp),
        V_true=cfg.vocab_size,
    )
