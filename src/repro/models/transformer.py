"""Generic decoder assembly for all 10 assigned architecture families.

A model is a repeating *pattern* of blocks (len-1 for uniform families;
('rec','rec','attn') for recurrentgemma). Full pattern repeats are scanned
(lax.scan over stacked params, with optional remat); the remainder layers are
unrolled. The same block functions serve training (full-sequence), prefill
(full-sequence + cache emission) and decode (single token + cache update),
in either bf16 training precision or the AMS-quantized serving path.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as A
from . import ffn as F
from . import moe as M
from . import ssm as S
from .common import Dims, apply_linear, make_linear, make_norm, model_dims, rms_norm
from .parallel import ParallelCtx


# ---------------------------------------------------------------------------
# Pattern / init
# ---------------------------------------------------------------------------
def layer_pattern(cfg) -> Tuple[str, ...]:
    if cfg.family == "hybrid":
        return cfg.block_pattern
    if cfg.family == "ssm":
        return ("mamba",)
    if cfg.family == "moe":
        return ("gqa_moe",)
    if cfg.attention == "mla":
        return ("mla",)
    return ("gqa",)


def init_block(key, cfg, dims: Dims, kind: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if kind == "mamba":
        return {"ln1": make_norm(cfg.d_model, dtype),
                "mixer": S.init_mamba(ks[0], cfg, dtype)}
    if kind == "rec":
        return {"ln1": make_norm(cfg.d_model, dtype),
                "mixer": S.init_rglru(ks[0], cfg, dtype),
                "ln2": make_norm(cfg.d_model, dtype),
                "ffn": F.init_ffn(ks[1], cfg.d_model, cfg.d_ff,
                                  cfg.ffn_activation, dtype)}
    if kind == "mla":
        return {"ln1": make_norm(cfg.d_model, dtype),
                "attn": A.init_mla(ks[0], cfg, dims, dtype),
                "ln2": make_norm(cfg.d_model, dtype),
                "ffn": F.init_ffn(ks[1], cfg.d_model, cfg.d_ff,
                                  cfg.ffn_activation, dtype)}
    if kind == "gqa_moe":
        return {"ln1": make_norm(cfg.d_model, dtype),
                "attn": A.init_gqa(ks[0], cfg, dims, dtype),
                "ln2": make_norm(cfg.d_model, dtype),
                "moe": M.init_moe(ks[1], cfg, dtype)}
    if kind in ("gqa", "attn"):
        return {"ln1": make_norm(cfg.d_model, dtype),
                "attn": A.init_gqa(ks[0], cfg, dims, dtype),
                "ln2": make_norm(cfg.d_model, dtype),
                "ffn": F.init_ffn(ks[1], cfg.d_model, cfg.d_ff,
                                  cfg.ffn_activation, dtype)}
    raise ValueError(f"unknown block kind {kind!r}")


def init_params(key, cfg, tp: int = 1, dtype=jnp.float32):
    """Full parameter pytree. Pattern repeats stacked [G, ...] under 'layers';
    remainder blocks unrolled under 'tail'."""
    dims = model_dims(cfg, tp)
    pat = layer_pattern(cfg)
    L, Pn = cfg.num_layers, len(pat)
    G, R = L // Pn, L % Pn
    k_emb, k_layers, k_tail, k_head = jax.random.split(key, 4)

    def init_group(k):
        kk = jax.random.split(k, Pn)
        return {f"sub{i}": init_block(kk[i], cfg, dims, pat[i], dtype)
                for i in range(Pn)}

    params: Dict[str, Any] = {
        "embed": {"w": (jax.random.normal(k_emb, (dims.V, cfg.d_model),
                                          jnp.float32) * 0.02).astype(dtype)},
        "layers": jax.vmap(init_group)(jax.random.split(k_layers, G)),
        "final_norm": make_norm(cfg.d_model, dtype),
        "lm_head": make_linear(k_head, cfg.d_model, dims.V, dtype=dtype),
    }
    if R:
        kk = jax.random.split(k_tail, R)
        params["tail"] = {f"sub{i}": init_block(kk[i], cfg, dims, pat[i], dtype)
                          for i in range(R)}
    return params


# ---------------------------------------------------------------------------
# Block application — full sequence (train / prefill)
# ---------------------------------------------------------------------------
def block_seq(p, x, kind, cfg, dims, *, policy=None, ctx: Optional[ParallelCtx],
              block_kv=1024, prefix_len=0, want_cache=False):
    """Returns (x, aux_loss, cache_or_None)."""
    aux = jnp.float32(0)
    cache = None
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "mamba":
        out, (conv_st, ssm_st) = S.mamba_train(p["mixer"], h, cfg, policy=policy)
        x = x + out
        if want_cache:
            cache = {"conv": conv_st, "ssm": ssm_st}
        return x, aux, cache
    if kind == "rec":
        out, (conv_st, rec_st) = S.rglru_train(p["mixer"], h, cfg, policy=policy)
        x = x + out
        if want_cache:
            cache = {"conv": conv_st, "state": rec_st}
    elif kind == "mla":
        out, kv = A.mla_attn_train(p["attn"], h, cfg, dims, policy=policy,
                                   block_kv=block_kv, prefix_len=prefix_len)
        x = x + out
        if want_cache:
            cache = {"kv": kv[:, :, None, :]}
    else:  # gqa / attn / gqa_moe
        window = cfg.sliding_window if kind == "attn" else 0
        out, (k, v) = A.gqa_attn_train(p["attn"], h, cfg, dims, policy=policy,
                                       block_kv=block_kv, prefix_len=prefix_len,
                                       window=window)
        x = x + out
        if want_cache:
            if window:
                k, v = (_to_ring(t, window) for t in (k, v))
            cache = {"k": k, "v": v}
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "gqa_moe":
        y, aux = M.moe_apply(p["moe"], h2, cfg, ctx, policy, phase="seq")
        x = x + y
    else:
        x = x + F.ffn_apply(p["ffn"], h2, cfg.ffn_activation, policy)
    return x, aux, cache


def _to_ring(kv: jnp.ndarray, window: int) -> jnp.ndarray:
    """Last `window` entries of [B, S, kv, hd] laid out by position % window."""
    B, Skv = kv.shape[0], kv.shape[1]
    W = min(window, Skv)
    tail = kv[:, Skv - W:]
    idx = (jnp.arange(Skv - W, Skv)) % window
    ring = jnp.zeros((B, window) + kv.shape[2:], kv.dtype)
    return ring.at[:, idx].set(tail)


# ---------------------------------------------------------------------------
# Block application — single-token decode
# ---------------------------------------------------------------------------
def _seq_core_wrap(ctx: ParallelCtx, n_caches: int):
    """shard_map wrapper for the insert+attend core with seq-sharded cache."""
    tp = ctx.tp_axis
    if n_caches == 2:  # gqa: (q, k_new, v_new, ck, cv, pos)
        in_specs = (P(None, None, None), P(None, None, None, None),
                    P(None, None, None, None),
                    P(None, tp, None, None), P(None, tp, None, None), P())
        out_specs = (P(None, None, None),
                     P(None, tp, None, None), P(None, tp, None, None))
    else:  # mla: (q_eff, kv_new, cache, pos)
        in_specs = (P(None, None, None), P(None, None, None, None),
                    P(None, tp, None, None), P())
        out_specs = (P(None, None, None), P(None, tp, None, None))

    def wrap(core):
        return ctx.shard_map(functools.partial(core, axis_name=tp),
                             in_specs=in_specs, out_specs=out_specs)
    return wrap


def pool_head_sharded(ctx: Optional[ParallelCtx], pool) -> bool:
    """True when the paged pool should run head-sharded over the model
    axis: a real tp>1 mesh and a kv-head count (axis ndim-2 of every pool
    plane) the axis divides. Non-divisible head counts stay replicated —
    the engine's `pool_shardings` applies the same rule, so the shard_map
    wrap and the pool placement always agree."""
    if ctx is None or ctx.mesh is None or ctx.tp <= 1:
        return False
    kv = jax.tree_util.tree_leaves(pool)[0].shape[-2]
    return kv % ctx.tp == 0


def _paged_core_wrap(ctx: ParallelCtx, pool, chunked: bool):
    """shard_map wrapper for the paged insert+attend core with the page
    pool HEAD-SHARDED over the model axis.

    Every pool plane — bf16 ``k``/``v`` [P, page, kv, hd] and the packed
    AMS ``hi``/``lsb``/``scale`` planes alike — splits on its kv-head axis
    (ndim-2); q and the new K/V vectors split on their head axes (the
    group-major projection layout keeps each q-head group on the device
    holding its kv head); pos / nvalid / block tables replicate. Inside
    the region quantize, scatter-insert and attend all see LOCAL head
    slices, so no page is ever gathered or resharded — the mesh only moves
    decode-sized activations, never KV bytes."""
    tp = ctx.tp_axis
    pool_specs = jax.tree_util.tree_map(
        lambda leaf: P(*([None] * (leaf.ndim - 2)), tp, None), pool)
    head4 = P(None, None, tp, None)
    q_spec = head4 if chunked else P(None, tp, None)
    bt = P(None, None)
    if chunked:  # (q, k_new, v_new, pool, pos, block_tables, nvalid)
        in_specs = (q_spec, head4, head4, pool_specs, P(), bt, P())
    else:        # (q, k_new, v_new, pool, pos, block_tables)
        in_specs = (q_spec, head4, head4, pool_specs, P(), bt)
    out_specs = (q_spec, pool_specs)

    def wrap(core):
        return ctx.shard_map(core, in_specs=in_specs, out_specs=out_specs)
    return wrap


def _replicate_model(x, ctx: Optional[ParallelCtx]):
    """Pin an activation replicated over the model axis (batch stays on the
    DP axes). The bit-exact TP serving layout N-shards every linear, so
    after each residual add this constraint is the ONLY cross-device step:
    an exact all-gather of a decode-sized activation. It keeps the next
    rms_norm's f32 mean over D device-complete — a model-sharded D would
    split that reduction and change the f32 rounding order vs tp=1."""
    if ctx is None or ctx.mesh is None or ctx.tp <= 1:
        return x
    dp = ctx.dp_axes if ctx.dp_axes else None
    import numpy as np
    if dp is not None:
        n = int(np.prod([ctx.mesh.shape[a] for a in dp]))
        if x.shape[0] % n != 0:
            dp = None
    spec = P(*((dp,) + (None,) * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(ctx.mesh, spec))


def block_decode(p, x, cache, pos, kind, cfg, dims, *, policy=None,
                 ctx: Optional[ParallelCtx], block_tables=None,
                 cache_cfg=None):
    """x: [B, 1, D]. Returns (x, new_cache)."""
    seq_sharded = ctx is not None and ctx.mesh is not None and ctx.seq_shard_cache
    paged = cache_cfg is not None and cache_cfg.paged
    # contiguous-cache attention impl (ref | pallas | pallas_interpret):
    # routes the GQA/MLA cores through the fused attention template
    attn_impl = cache_cfg.impl if cache_cfg is not None else "ref"
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "mamba":
        out, (conv_st, ssm_st) = S.mamba_decode(
            p["mixer"], h, cache["conv"], cache["ssm"], cfg, policy=policy)
        return x + out, {"conv": conv_st, "ssm": ssm_st}
    if kind == "rec":
        out, (conv_st, rec_st) = S.rglru_decode(
            p["mixer"], h, cache["conv"], cache["state"], cfg, policy=policy)
        x = x + out
        cache = {"conv": conv_st, "state": rec_st}
    elif kind == "mla":
        wrap = _seq_core_wrap(ctx, 1) if seq_sharded else None
        out, ckv = A.mla_attn_decode(p["attn"], h, cache["kv"], pos, cfg, dims,
                                     policy=policy, core_wrap=wrap,
                                     attn_impl=attn_impl)
        x = x + out
        cache = {"kv": ckv}
    elif paged:
        wrap = (_paged_core_wrap(ctx, cache, chunked=False)
                if pool_head_sharded(ctx, cache) else None)
        out, cache = A.gqa_attn_decode_paged(
            p["attn"], h, cache, pos, block_tables, cfg, dims,
            policy=policy, cache_cfg=cache_cfg, core_wrap=wrap)
        x = _replicate_model(x + out, ctx)
    else:
        window = cfg.sliding_window if kind == "attn" else 0
        wrap = _seq_core_wrap(ctx, 2) if seq_sharded else None
        out, (ck, cv) = A.gqa_attn_decode(
            p["attn"], h, cache["k"], cache["v"], pos, cfg, dims,
            policy=policy, core_wrap=wrap, window=window, ring=bool(window),
            attn_impl=attn_impl)
        x = x + out
        cache = {"k": ck, "v": cv}
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "gqa_moe":
        y, _ = M.moe_apply(p["moe"], h2, cfg, ctx, policy, phase="decode")
        x = x + y
    else:
        x = x + F.ffn_apply(p["ffn"], h2, cfg.ffn_activation, policy)
    if paged:
        x = _replicate_model(x, ctx)
    return x, cache


def _seq_core_wrap_chunk(ctx: ParallelCtx, n_caches: int):
    """shard_map wrapper for the CHUNKED insert+attend core (seq-sharded
    cache): same layout as `_seq_core_wrap` with a chunk dim on q/k/v and
    the extra replicated [B] nvalid arg."""
    tp = ctx.tp_axis
    P4 = P(None, None, None, None)
    if n_caches == 2:  # gqa: (q, k_new, v_new, ck, cv, pos, nvalid)
        in_specs = (P4, P4, P4,
                    P(None, tp, None, None), P(None, tp, None, None),
                    P(), P())
        out_specs = (P4, P(None, tp, None, None), P(None, tp, None, None))
    else:  # mla: (q_eff, kv_new, cache, pos, nvalid)
        in_specs = (P4, P4, P(None, tp, None, None), P(), P())
        out_specs = (P4, P(None, tp, None, None))

    def wrap(core):
        return ctx.shard_map(functools.partial(core, axis_name=tp),
                             in_specs=in_specs, out_specs=out_specs)
    return wrap


def block_decode_chunk(p, x, cache, pos, nvalid, kind, cfg, dims, *,
                       policy=None, ctx: Optional[ParallelCtx],
                       block_tables=None, cache_cfg=None):
    """Ragged multi-token analogue of `block_decode`: x [B, c, D], per-slot
    start positions ``pos`` [B] and valid counts ``nvalid`` [B]. Supports
    the pure-attention families only (gqa / gqa_moe / mla — see
    `check_chunked_support`); recurrent blocks need a serial state update
    per token and keep the one-token step. Returns (x, new_cache)."""
    if kind not in ("gqa", "gqa_moe", "mla"):
        raise NotImplementedError(
            f"chunked decode does not support {kind!r} blocks")
    seq_sharded = ctx is not None and ctx.mesh is not None and ctx.seq_shard_cache
    paged = cache_cfg is not None and cache_cfg.paged
    attn_impl = cache_cfg.impl if cache_cfg is not None else "ref"
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "mla":
        wrap = _seq_core_wrap_chunk(ctx, 1) if seq_sharded else None
        out, ckv = A.mla_attn_decode_chunk(p["attn"], h, cache["kv"], pos,
                                           nvalid, cfg, dims, policy=policy,
                                           core_wrap=wrap,
                                           attn_impl=attn_impl)
        x = x + out
        cache = {"kv": ckv}
    elif paged:
        wrap = (_paged_core_wrap(ctx, cache, chunked=True)
                if pool_head_sharded(ctx, cache) else None)
        out, cache = A.gqa_attn_decode_paged_chunk(
            p["attn"], h, cache, pos, nvalid, block_tables, cfg, dims,
            policy=policy, cache_cfg=cache_cfg, core_wrap=wrap)
        x = _replicate_model(x + out, ctx)
    else:
        wrap = _seq_core_wrap_chunk(ctx, 2) if seq_sharded else None
        out, (ck, cv) = A.gqa_attn_decode_chunk(
            p["attn"], h, cache["k"], cache["v"], pos, nvalid, cfg, dims,
            policy=policy, core_wrap=wrap, attn_impl=attn_impl)
        x = x + out
        cache = {"k": ck, "v": cv}
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "gqa_moe":
        y, _ = M.moe_apply(p["moe"], h2, cfg, ctx, policy, phase="decode")
        x = x + y
    else:
        x = x + F.ffn_apply(p["ffn"], h2, cfg.ffn_activation, policy)
    if paged:
        x = _replicate_model(x, ctx)
    return x, cache


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------
def block_cache_shape(cfg, dims: Dims, kind: str, B: int, cap: int, dtype):
    if kind == "mamba":
        return {"conv": jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner), dtype),
                "ssm": jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32)}
    if kind == "rec":
        return {"conv": jnp.zeros((B, 3, cfg.lru_width), dtype),
                "state": jnp.zeros((B, cfg.lru_width), jnp.float32)}
    if kind == "mla":
        c = cfg.kv_lora_rank + cfg.qk_rope_dim
        return {"kv": jnp.zeros((B, cap, 1, c), dtype)}
    S_cap = min(cap, cfg.sliding_window) if (kind == "attn" and cfg.sliding_window) else cap
    if kind == "attn" and cfg.sliding_window:
        S_cap = cfg.sliding_window
    return {"k": jnp.zeros((B, S_cap, dims.kv, dims.hd), dtype),
            "v": jnp.zeros((B, S_cap, dims.kv, dims.hd), dtype)}


def check_paged_support(cfg):
    """Paged KV caching covers plain GQA attention layers only (for now):
    sliding-window ring caches, MLA's compressed stream, and SSM/RG-LRU
    recurrent states keep their contiguous layouts (docs/paged_cache.md
    §Extensions)."""
    pat = layer_pattern(cfg)
    bad = [k for k in pat if k not in ("gqa", "gqa_moe")]
    if bad:
        raise NotImplementedError(
            f"paged KV cache supports gqa/gqa_moe layers only; "
            f"{cfg.name} has {sorted(set(bad))}")
    if cfg.sliding_window:
        raise NotImplementedError(
            "paged KV cache does not support sliding-window ring caches yet")


def check_chunked_support(cfg):
    """Chunked (multi-token) decode covers pure-attention families: plain
    GQA, MoE-GQA and absorbed MLA. Mamba / RG-LRU recurrences integrate
    state token-by-token (a masked multi-token recurrent scan is the
    documented next step), and sliding-window ring caches would need
    chunk-aware ring inserts — those families keep the one-token step."""
    pat = layer_pattern(cfg)
    bad = [k for k in pat if k not in ("gqa", "gqa_moe", "mla")]
    if bad:
        raise NotImplementedError(
            f"chunked prefill supports gqa/gqa_moe/mla layers only; "
            f"{cfg.name} has {sorted(set(bad))}")
    if cfg.sliding_window:
        raise NotImplementedError(
            "chunked prefill does not support sliding-window ring caches yet")


def make_cache(cfg, B: int, cap: int, tp: int = 1, dtype=jnp.bfloat16,
               cache_cfg=None):
    """Zero-initialized cache pytree matching the params layout.

    With a paged ``cache_cfg`` the per-layer KV leaves are PAGE POOLS
    (`repro.cache.pool` layout, no batch dim — slots address them through
    block tables); otherwise the fixed [B, cap] slot layout."""
    dims = model_dims(cfg, tp)
    pat = layer_pattern(cfg)
    L, Pn = cfg.num_layers, len(pat)
    G, R = L // Pn, L % Pn
    paged = cache_cfg is not None and cache_cfg.paged
    if paged:
        from repro.cache import make_gqa_page_pool
        check_paged_support(cfg)

    def block(kind):
        if paged:
            return make_gqa_page_pool(cache_cfg, dims.kv, dims.hd, dtype)
        return block_cache_shape(cfg, dims, kind, B, cap, dtype)

    def group():
        return {f"sub{i}": block(pat[i]) for i in range(Pn)}

    cache = {"layers": jax.tree.map(
        lambda a: jnp.broadcast_to(a, (G,) + a.shape).copy() if G else a, group())}
    if R:
        cache["tail"] = {f"sub{i}": block(pat[i]) for i in range(R)}
    return cache


def reset_cache_slot(cache, slot):
    """Zero batch row `slot` of every cache leaf (slot reuse in the engine).

    KV entries beyond a slot's length are masked by position anyway, but the
    SSM / RG-LRU recurrent states integrate whatever an idle slot was fed, so
    a freed slot must be cleared before a new request is admitted into it.
    Stacked pattern-repeat leaves carry batch at axis 1 ([G, B, ...]); tail
    leaves at axis 0.
    """
    out = {"layers": jax.tree.map(lambda l: l.at[:, slot].set(0),
                                  cache["layers"])}
    if "tail" in cache:
        out["tail"] = jax.tree.map(lambda l: l.at[slot].set(0), cache["tail"])
    return out


# ---------------------------------------------------------------------------
# Full model: train forward / prefill / decode
# ---------------------------------------------------------------------------
def _embed(params, tokens, cfg, dims, prefix_embeds=None, dtype=jnp.bfloat16,
           ctx: Optional[ParallelCtx] = None):
    x = params["embed"]["w"].astype(dtype)[tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(dtype), x], axis=1)
    return _constrain_batch(x, ctx)


def _constrain_batch(x, ctx: Optional[ParallelCtx]):
    """Pin the batch dim to the DP axes after the embedding gather.

    The gather of a model-sharded embedding table with data-sharded indices
    loses the batch sharding in SPMD propagation — without this constraint
    the whole model body runs replicated over `data` (measured: 16x
    redundant flops on every train cell)."""
    if ctx is None or ctx.mesh is None or not ctx.dp_axes:
        return x
    import numpy as np
    n = int(np.prod([ctx.mesh.shape[a] for a in ctx.dp_axes]))
    if x.shape[0] % n != 0:
        return x
    spec = P(*((ctx.dp_axes,) + (None,) * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(ctx.mesh, spec))


def _head(params, x, cfg, dims, policy=None):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = apply_linear(params["lm_head"], x, policy)
    return logits.astype(jnp.float32) + dims.vocab_mask_bias[None, None, :]


def forward_seq(params, tokens, cfg, *, tp=1, policy=None, ctx=None,
                remat=True, block_kv=1024, prefix_embeds=None,
                want_cache=False, dtype=jnp.bfloat16):
    """Full-sequence forward. Returns (logits, aux, cache_or_None).

    train: want_cache=False; prefill: want_cache=True (logits for last token
    come from the same pass)."""
    dims = model_dims(cfg, tp)
    pat = layer_pattern(cfg)
    L, Pn = cfg.num_layers, len(pat)
    G, R = L // Pn, L % Pn
    prefix_len = 0 if prefix_embeds is None else prefix_embeds.shape[1]
    x = _embed(params, tokens, cfg, dims, prefix_embeds, dtype, ctx=ctx)

    def group_fn(carry, gp):
        x, aux = carry
        caches = {}
        for i in range(Pn):
            x, a, c = block_seq(gp[f"sub{i}"], x, pat[i], cfg, dims,
                                policy=policy, ctx=ctx, block_kv=block_kv,
                                prefix_len=prefix_len, want_cache=want_cache)
            aux = aux + a
            if want_cache:
                caches[f"sub{i}"] = c
        return (x, aux), (caches if want_cache else None)

    fn = jax.checkpoint(group_fn) if remat else group_fn
    (x, aux), layer_caches = jax.lax.scan(fn, (x, jnp.float32(0)),
                                          params["layers"])
    cache = {"layers": layer_caches} if want_cache else None
    if R:
        tail_caches = {}
        for i in range(R):
            x, a, c = block_seq(params["tail"][f"sub{i}"], x, pat[i], cfg, dims,
                                policy=policy, ctx=ctx, block_kv=block_kv,
                                prefix_len=prefix_len, want_cache=want_cache)
            aux = aux + a
            if want_cache:
                tail_caches[f"sub{i}"] = c
        if want_cache:
            cache["tail"] = tail_caches
    logits = _head(params, x, cfg, dims, policy)
    return logits, aux, cache


def decode_step(params, token, cache, pos, cfg, *, tp=1, policy=None,
                ctx=None, dtype=jnp.bfloat16, embeds=None, embed_mask=None,
                block_tables=None, cache_cfg=None, nvalid=None, ndraft=None,
                n_logits=1):
    """One decode step. token: [B] int32; pos: scalar int32 (insert position)
    or [B] int32 per-slot positions (continuous-batching engine; a negative
    position marks an idle slot whose cache write is suppressed).

    RAGGED MULTI-TOKEN STEP: with token [B, C] int32 the step consumes a
    variable-length block per slot — ``pos`` [B] is each slot's START
    position and ``nvalid`` [B] its valid token count this tick (prefilling
    slots bring a prompt chunk, decoding slots bring 1, idle slots 0).
    Positions are derived in-step (pos + chunk index), intra-chunk causality
    is enforced through per-query attention lengths, and the returned logits
    are taken at each slot's LAST valid token. Pure-attention families only
    (`check_chunked_support`).

    With a paged ``cache_cfg``, ``block_tables`` [B, max_pages] int32 maps
    each slot's logical pages to physical pool pages (same row for every
    layer); the cache pytree holds page pools instead of slot tensors.

    ``embeds`` [B, D] + ``embed_mask`` [B] bool optionally override the token
    embedding per slot (``[B, C, D]`` / ``[B, C]`` in the ragged step) — the
    engine uses this to stream modality prefix embeddings (VLM patches /
    audio frames) through the same decode step during chunked prefill.

    SPECULATIVE SCORING (``n_logits`` = K+1 > 1, ragged step only): the
    chunk's last ``ndraft[b]`` tokens are DRAFT tokens; logits come back
    [B, K+1, V] at positions ``nvalid-1-ndraft .. nvalid-1`` (clipped into
    the chunk) — row j scores the token following draft j, row 0 is
    exactly the last-valid-token row the plain step returns, so slots with
    ``ndraft == 0`` (prefill / plain decode) are unchanged.

    Returns (logits [B, V] — or [B, n_logits, V] when n_logits > 1 —,
    new cache)."""
    if token.ndim == 2:
        return _decode_step_chunk(params, token, cache, pos, nvalid, cfg,
                                  tp=tp, policy=policy, ctx=ctx, dtype=dtype,
                                  embeds=embeds, embed_mask=embed_mask,
                                  block_tables=block_tables,
                                  cache_cfg=cache_cfg, ndraft=ndraft,
                                  n_logits=n_logits)
    if n_logits != 1:
        raise ValueError("n_logits > 1 requires the ragged [B, C] step")
    dims = model_dims(cfg, tp)
    pat = layer_pattern(cfg)
    L, Pn = cfg.num_layers, len(pat)
    G, R = L // Pn, L % Pn
    x = _embed(params, token[:, None], cfg, dims, None, dtype, ctx=ctx)
    if embeds is not None:
        mask = (embed_mask if embed_mask is not None
                else jnp.ones(token.shape, bool))
        x = jnp.where(mask[:, None, None], embeds[:, None, :].astype(x.dtype), x)

    # Caches ride the scan xs/ys (slice in, updated slice out). We also
    # tried carrying the stacked cache and updating per-layer slices in
    # place — it measured 2.3x WORSE (XLA rematerializes the carried-buffer
    # slices; scan's native xs/ys streaming is already the cheaper path).
    # See EXPERIMENTS.md §Perf (refuted iteration).
    def group_fn(x, xs):
        gp, gcache = xs
        new_caches = {}
        for i in range(Pn):
            x, nc = block_decode(gp[f"sub{i}"], x, gcache[f"sub{i}"], pos,
                                 pat[i], cfg, dims, policy=policy, ctx=ctx,
                                 block_tables=block_tables,
                                 cache_cfg=cache_cfg)
            new_caches[f"sub{i}"] = nc
        return x, new_caches

    x, new_layer_caches = jax.lax.scan(group_fn, x,
                                       (params["layers"], cache["layers"]))
    new_cache = {"layers": new_layer_caches}
    if R:
        tails = {}
        for i in range(R):
            x, nc = block_decode(params["tail"][f"sub{i}"], x,
                                 cache["tail"][f"sub{i}"], pos, pat[i], cfg,
                                 dims, policy=policy, ctx=ctx,
                                 block_tables=block_tables,
                                 cache_cfg=cache_cfg)
            tails[f"sub{i}"] = nc
        new_cache["tail"] = tails
    logits = _head(params, x, cfg, dims, policy)
    return logits[:, 0], new_cache


def _decode_step_chunk(params, token, cache, pos, nvalid, cfg, *, tp=1,
                       policy=None, ctx=None, dtype=jnp.bfloat16,
                       embeds=None, embed_mask=None, block_tables=None,
                       cache_cfg=None, ndraft=None, n_logits=1):
    """Ragged multi-token step body (see `decode_step`): token [B, C],
    pos/nvalid [B]. Returns (logits [B, V] at each slot's last valid
    token — or [B, n_logits, V] at the last ndraft+1 valid positions when
    speculating — and the new cache)."""
    dims = model_dims(cfg, tp)
    pat = layer_pattern(cfg)
    L, Pn = cfg.num_layers, len(pat)
    G, R = L // Pn, L % Pn
    nvalid = jnp.asarray(nvalid, jnp.int32)
    x = _embed(params, token, cfg, dims, None, dtype, ctx=ctx)    # [B, C, D]
    if embeds is not None:
        mask = (embed_mask if embed_mask is not None
                else jnp.ones(token.shape, bool))
        x = jnp.where(mask[:, :, None], embeds.astype(x.dtype), x)

    def group_fn(x, xs):
        gp, gcache = xs
        new_caches = {}
        for i in range(Pn):
            x, nc = block_decode_chunk(gp[f"sub{i}"], x, gcache[f"sub{i}"],
                                       pos, nvalid, pat[i], cfg, dims,
                                       policy=policy, ctx=ctx,
                                       block_tables=block_tables,
                                       cache_cfg=cache_cfg)
            new_caches[f"sub{i}"] = nc
        return x, new_caches

    x, new_layer_caches = jax.lax.scan(group_fn, x,
                                       (params["layers"], cache["layers"]))
    new_cache = {"layers": new_layer_caches}
    if R:
        tails = {}
        for i in range(R):
            x, nc = block_decode_chunk(params["tail"][f"sub{i}"], x,
                                       cache["tail"][f"sub{i}"], pos, nvalid,
                                       pat[i], cfg, dims, policy=policy,
                                       ctx=ctx, block_tables=block_tables,
                                       cache_cfg=cache_cfg)
            tails[f"sub{i}"] = nc
        new_cache["tail"] = tails
    # logits only at each slot's LAST valid token — the head (the widest
    # matmul in the step) never runs over discarded prefill positions.
    # Speculative scoring widens the gather to the last ndraft+1 valid
    # positions ([B, n_logits, D]); index 0 degenerates to the plain
    # last-valid row for slots with ndraft == 0, so non-speculating slots
    # see identical logits either way.
    if n_logits > 1:
        nd = (jnp.zeros_like(nvalid) if ndraft is None
              else jnp.asarray(ndraft, jnp.int32))
        sel = jnp.clip(nvalid[:, None] - 1 - nd[:, None]
                       + jnp.arange(n_logits, dtype=jnp.int32)[None, :],
                       0, token.shape[1] - 1)                     # [B, K+1]
        x_sel = jnp.take_along_axis(x, jnp.broadcast_to(
            sel[:, :, None], sel.shape + (x.shape[2],)), axis=1)  # [B, K+1, D]
        return _head(params, x_sel, cfg, dims, policy), new_cache
    last = jnp.clip(nvalid - 1, 0, token.shape[1] - 1)[:, None, None]
    x_last = jnp.take_along_axis(x, jnp.broadcast_to(
        last, (x.shape[0], 1, x.shape[2])), axis=1)               # [B, 1, D]
    logits = _head(params, x_last, cfg, dims, policy)
    return logits[:, 0], new_cache
