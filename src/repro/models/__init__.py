"""Model zoo: generic decoder assembly covering all assigned families."""

from .common import model_dims, quantize_params  # noqa: F401
from .parallel import NO_CTX, ParallelCtx  # noqa: F401
from .transformer import (  # noqa: F401
    check_chunked_support,
    check_paged_support,
    decode_step,
    forward_seq,
    init_params,
    layer_pattern,
    make_cache,
    reset_cache_slot,
)
