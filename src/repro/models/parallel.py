"""Parallelism context threaded through model code.

Keeps the model definitions mesh-agnostic: with ``ctx=None`` (unit tests,
single host) every layer runs its dense/local fallback; with a production
mesh the context enables expert parallelism (shard_map over the model axis)
and sequence-sharded decode caches.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Optional[object] = None          # jax.sharding.Mesh
    dp_axes: Tuple[str, ...] = ()          # mesh axes the batch is sharded over
    tp_axis: Optional[str] = None          # tensor/expert-parallel axis
    seq_shard_cache: bool = False          # decode KV cache sharded over tp_axis

    @property
    def tp(self) -> int:
        if self.mesh is None or self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]

    @property
    def dp(self) -> Tuple[str, ...] | None:
        return self.dp_axes if self.dp_axes else None

    def shard_map(self, f, in_specs, out_specs):
        """Manual collectives over the tp axis only; other axes stay auto
        (`launch.mesh.compat_shard_map` picks the jax spelling)."""
        assert self.mesh is not None and self.tp_axis is not None
        from repro.launch.mesh import compat_shard_map
        return compat_shard_map(f, self.mesh, {self.tp_axis},
                                in_specs=in_specs, out_specs=out_specs)


NO_CTX = ParallelCtx()


def batch_spec(ctx: Optional[ParallelCtx], *rest) -> P:
    """PartitionSpec with the batch dim over dp axes, remaining dims as given."""
    if ctx is None or not ctx.dp_axes:
        return P(*((None,) + rest))
    return P(*((ctx.dp_axes,) + rest))
