"""State-space blocks: Mamba-1 (falcon-mamba) and RG-LRU (recurrentgemma).

Both are linear recurrences h_t = a_t * h_{t-1} + b_t. Training/prefill use a
*chunked* scan — lax.scan over chunks carrying the boundary state, with an
associative scan inside each chunk — so the materialized state tensor is
O(B * chunk * d * n) instead of O(B * S * d * n); decode is the single-step
recurrence (O(1) in sequence length: these are the archs that run the
long_500k shape).

Scan parameters (A_log, D, dt bias, Λ) are small and stay f32 (never
quantized); all projections are quantizable linears.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import apply_linear, make_linear


# ---------------------------------------------------------------- scan core
def chunked_linear_scan(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray,
                        chunk: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t * h_{t-1} + b_t along axis 1. a, b: [B, S, ...]; h0: [B, ...].

    Returns (h over all t: [B, S, ...], final state [B, ...]).
    """
    B, S = a.shape[:2]
    ch = min(chunk, S)
    nc = -(-S // ch)
    pad = nc * ch - S
    if pad:
        a = jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
                    constant_values=1.0)
        b = jnp.pad(b, [(0, 0), (0, pad)] + [(0, 0)] * (b.ndim - 2))
    ac = a.reshape((B, nc, ch) + a.shape[2:]).transpose((1, 0, 2) + tuple(range(3, a.ndim + 1)))
    bc = b.reshape((B, nc, ch) + b.shape[2:]).transpose((1, 0, 2) + tuple(range(3, b.ndim + 1)))

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    def body(h, xs):
        aj, bj = xs  # [B, ch, ...]
        pa, pb = jax.lax.associative_scan(combine, (aj, bj), axis=1)
        hj = pb + pa * h[:, None]
        return hj[:, -1], hj

    hN, hs = jax.lax.scan(body, h0, (ac, bc))
    hs = hs.transpose((1, 0, 2) + tuple(range(3, b.ndim + 1)))
    hs = hs.reshape((B, nc * ch) + b.shape[2:])
    return hs[:, :S], hN


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: Optional[jnp.ndarray],
                  state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: [B, S, C]; w: [width, C]; state: [B, width-1, C].

    Returns (y [B, S, C], new_state [B, width-1, C]).
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xe = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # [B, S+w-1, C]
    y = sum(xe[:, i: i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
            for i in range(width))
    if b is not None:
        y = y + b.astype(y.dtype)
    new_state = xe[:, -(width - 1):, :] if width > 1 else state
    return y, new_state


# -------------------------------------------------------------------- Mamba1
def init_mamba(key, cfg, dtype=jnp.float32):
    D, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dt_rank = cfg.dt_rank or max(1, D // 16)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": make_linear(ks[0], D, 2 * di, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32)
                   * (1.0 / np.sqrt(cfg.ssm_conv))).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": make_linear(ks[2], di, dt_rank + 2 * n, dtype=dtype),
        "dt_proj": make_linear(ks[3], dt_rank, di, bias=True, dtype=dtype),
        "A_log": jnp.log(A),           # f32 [di, n]
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": make_linear(ks[4], di, D, dtype=dtype),
    }


def _mamba_core(p, xc, cfg, policy):
    """xc: [B, S, di] post-conv activations -> (da, db) scan elements."""
    n = cfg.ssm_state
    dt_rank = cfg.dt_rank or max(1, cfg.d_model // 16)
    xdb = apply_linear(p["x_proj"], xc, policy)
    dt_r = xdb[..., :dt_rank]
    Bc = xdb[..., dt_rank: dt_rank + n]
    Cc = xdb[..., dt_rank + n:]
    dt = jax.nn.softplus(apply_linear(p["dt_proj"], dt_r, policy).astype(jnp.float32))
    A = -jnp.exp(p["A_log"])  # [di, n]
    da = jnp.exp(dt[..., None] * A[None, None])                      # [B,S,di,n]
    db = (dt[..., None] * Bc[:, :, None, :].astype(jnp.float32)
          * xc[..., None].astype(jnp.float32))                       # [B,S,di,n]
    return da, db, Cc


def mamba_train(p, x, cfg, *, policy=None, chunk=256):
    """x: [B, S, D] -> (y [B, S, D], (conv_state, ssm_state) final)."""
    di, n = cfg.d_inner, cfg.ssm_state
    xz = apply_linear(p["in_proj"], x, policy)
    x_in, z = xz[..., :di], xz[..., di:]
    xc, conv_state = causal_conv1d(x_in, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    da, db, Cc = _mamba_core(p, xc, cfg, policy)
    h0 = jnp.zeros((x.shape[0], di, n), jnp.float32)
    hs, hN = chunked_linear_scan(da, db, h0, chunk)                  # [B,S,di,n]
    y = jnp.einsum("bsdn,bsn->bsd", hs, Cc.astype(jnp.float32))
    y = y + p["D"][None, None] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return apply_linear(p["out_proj"], y, policy), (conv_state, hN)


def mamba_decode(p, x, conv_state, ssm_state, cfg, *, policy=None):
    """x: [B, 1, D]; conv_state [B, w-1, di]; ssm_state [B, di, n] f32."""
    di, n = cfg.d_inner, cfg.ssm_state
    xz = apply_linear(p["in_proj"], x, policy)
    x_in, z = xz[..., :di], xz[..., di:]
    xc, conv_state = causal_conv1d(x_in, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    da, db, Cc = _mamba_core(p, xc, cfg, policy)
    h = da[:, 0] * ssm_state + db[:, 0]                              # [B,di,n]
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0].astype(jnp.float32))
    y = y + p["D"][None] * xc[:, 0].astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z[:, 0]))[:, None]
    return apply_linear(p["out_proj"], y, policy), (conv_state, h)


# -------------------------------------------------------------------- RG-LRU
def init_rglru(key, cfg, dtype=jnp.float32):
    D, W = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    return {
        "in_x": make_linear(ks[0], D, W, dtype=dtype),
        "in_gate": make_linear(ks[1], D, W, dtype=dtype),
        "conv_w": (jax.random.normal(ks[2], (4, W), jnp.float32) * 0.5).astype(dtype),
        "conv_b": jnp.zeros((W,), dtype),
        "w_rec_gate": make_linear(ks[3], W, W, dtype=dtype),   # r_t
        "w_in_gate": make_linear(ks[4], W, W, dtype=dtype),    # i_t
        "lam": jnp.full((W,), 2.0, jnp.float32),               # Λ
        "out_proj": make_linear(ks[5], W, D, dtype=dtype),
    }


def _rglru_elems(p, u, policy):
    """u: [B, S, W] -> (a, b) recurrence elements, f32."""
    r = jax.nn.sigmoid(apply_linear(p["w_rec_gate"], u, policy).astype(jnp.float32))
    i = jax.nn.sigmoid(apply_linear(p["w_in_gate"], u, policy).astype(jnp.float32))
    log_a = -8.0 * jax.nn.sigmoid(p["lam"])[None, None] * r       # [B,S,W]
    a = jnp.exp(log_a)
    gated = i * u.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated
    return a, b


def rglru_train(p, x, cfg, *, policy=None, chunk=256):
    gate = jax.nn.gelu(apply_linear(p["in_gate"], x, policy))
    u = apply_linear(p["in_x"], x, policy)
    u, conv_state = causal_conv1d(u, p["conv_w"], p["conv_b"])
    a, b = _rglru_elems(p, u, policy)
    h0 = jnp.zeros((x.shape[0], cfg.lru_width), jnp.float32)
    hs, hN = chunked_linear_scan(a, b, h0, chunk)                  # [B,S,W]
    y = hs.astype(x.dtype) * gate
    return apply_linear(p["out_proj"], y, policy), (conv_state, hN)


def rglru_decode(p, x, conv_state, rec_state, cfg, *, policy=None):
    gate = jax.nn.gelu(apply_linear(p["in_gate"], x, policy))
    u = apply_linear(p["in_x"], x, policy)
    u, conv_state = causal_conv1d(u, p["conv_w"], p["conv_b"], conv_state)
    a, b = _rglru_elems(p, u, policy)
    h = a[:, 0] * rec_state + b[:, 0]                              # [B,W]
    y = h[:, None].astype(x.dtype) * gate
    return apply_linear(p["out_proj"], y, policy), (conv_state, h)
