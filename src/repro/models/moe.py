"""Mixture-of-Experts FFN: top-k router + experts, EP over the model axis.

Two execution paths with identical math (tested against each other):

  * ``moe_dense``  — every expert computed on every token, combined with the
    (sparse) gate matrix. Exact; used on small configs / unit tests and as
    the oracle for the EP path.
  * ``moe_ep``     — production path. shard_map over the `model` axis: each
    device holds E/tp experts; it gathers its top-C local tokens (capacity
    dropping, MaxText-style), runs its expert FFN, scatters back weighted by
    the gate, and a psum over the model axis combines the top-k partial sums.
    Activations stay sharded over data axes throughout (partial shard_map).

Router runs in f32 and is never quantized (policy excludes 'router').
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import apply_linear, make_linear
from .ffn import ffn_apply, init_ffn
from .parallel import ParallelCtx


def init_moe(key, cfg, dtype=jnp.float32):
    E = cfg.num_experts
    ks = jax.random.split(key, 3)
    expert_keys = jax.random.split(ks[0], E)
    experts = jax.vmap(
        lambda k: init_ffn(k, cfg.d_model, cfg.d_ff, cfg.ffn_activation, dtype)
    )(expert_keys)
    p = {
        "router": make_linear(ks[1], cfg.d_model, E, dtype=jnp.float32),
        "experts": experts,  # leaves stacked [E, ...]
    }
    if cfg.moe_shared_expert_ff:
        p["shared"] = init_ffn(ks[2], cfg.d_model, cfg.moe_shared_expert_ff,
                               cfg.ffn_activation, dtype)
    return p


def _gates(p, x, cfg):
    """softmax router + top-k: returns dense [T, E] combine weights."""
    logits = apply_linear(p["router"], x.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_v, top_i = jax.lax.top_k(probs, cfg.experts_per_token)
    top_v = top_v / jnp.maximum(top_v.sum(-1, keepdims=True), 1e-9)  # renorm
    combine = jnp.zeros_like(probs)
    combine = combine.at[
        jnp.arange(x.shape[0])[:, None], top_i
    ].set(top_v.astype(jnp.float32))
    return combine, probs


def load_balance_loss(combine: jnp.ndarray, probs: jnp.ndarray, E: int):
    """Switch-style aux loss: E * <f_e> . <p_e>."""
    frac = (combine > 0).astype(jnp.float32).mean(axis=0)
    imp = probs.mean(axis=0)
    return E * jnp.sum(frac * imp)


def moe_dense(p, x, cfg, policy=None):
    """Exact dense-combine MoE. x: [B, S, D] -> ([B, S, D], aux_loss)."""
    B, S, D = x.shape
    xf = x.reshape(B * S, D)
    combine, probs = _gates(p, xf, cfg)  # [T, E]

    def one_expert(ep):
        return ffn_apply(ep, xf, cfg.ffn_activation, policy)  # [T, D]

    ys = jax.vmap(one_expert)(p["experts"])  # [E, T, D]
    y = jnp.einsum("te,etd->td", combine.astype(ys.dtype), ys)
    aux = load_balance_loss(combine, probs, cfg.num_experts)
    if "shared" in p:
        y = y + ffn_apply(p["shared"], xf, cfg.ffn_activation, policy)
    return y.reshape(B, S, D).astype(x.dtype), aux


def moe_ep(p, x, cfg, ctx: ParallelCtx, policy=None):
    """Expert-parallel MoE (shard_map over ctx.tp_axis). x: [B, S, D]."""
    B, S, D = x.shape
    E, topk = cfg.num_experts, cfg.experts_per_token
    tp = ctx.tp
    assert E % tp == 0, f"num_experts={E} must divide over tp={tp}"
    e_loc = E // tp

    def inner(xf, router, experts):
        # xf: [T, D] local tokens (data axes remain auto-sharded);
        # experts: leaves [e_loc, ...]
        T = xf.shape[0]
        cap = min(T, max(1, math.ceil(T * topk / E * cfg.moe_capacity_factor)))
        combine, probs = _gates({"router": router}, xf, cfg)  # [T, E]
        my0 = jax.lax.axis_index(ctx.tp_axis) * e_loc
        y = jnp.zeros((T, D), jnp.float32)
        for j in range(e_loc):
            w_e = combine[:, my0 + j]                  # [T]
            _, order = jax.lax.top_k(w_e, cap)         # top-C tokens
            xe = xf[order]                             # [C, D]
            ep = jax.tree.map(lambda a: a[j], experts)
            he = ffn_apply(ep, xe, cfg.ffn_activation, policy)
            # indices within one expert are unique -> scatter-set (its vjp is
            # a plain gather; scatter-add's transpose trips an XLA SPMD bug)
            y = y + jnp.zeros((T, D), jnp.float32).at[order].set(
                he.astype(jnp.float32) * w_e[order, None])
        y = jax.lax.psum(y, ctx.tp_axis)
        aux = load_balance_loss(combine, probs, E)
        return y, aux

    router_spec = jax.tree.map(lambda _: P(None, None), p["router"])
    experts_specs = jax.tree.map(
        lambda a: P(*((ctx.tp_axis,) + (None,) * (a.ndim - 1))), p["experts"])
    f = ctx.shard_map(
        inner,
        in_specs=(P(None, None), router_spec, experts_specs),
        out_specs=(P(None, None), P()),
    )
    xf = x.reshape(B * S, D)
    y, aux = f(xf, p["router"], p["experts"])
    y = y.reshape(B, S, D).astype(x.dtype)
    if "shared" in p:
        y = y + ffn_apply(p["shared"], x, cfg.ffn_activation, policy)
    return y, aux


def moe_tp(p, x, cfg, ctx: ParallelCtx, policy=None):
    """Expert-sequential tensor-parallel MoE (pure pjit, differentiable).

    Each expert's FFN is TP-sharded over the model axis like a dense FFN;
    experts run as a lax.scan with capacity-gathered token subsets. Used for
    TRAINING: the shard_map EP path trips an XLA SPMD check-failure under
    autodiff (hlo_instruction.cc "Invalid binary instruction opcode copy" —
    see DESIGN.md §Known-workarounds); serving keeps true EP.
    """
    import jax.numpy as jnp

    B, S, D = x.shape
    E, topk = cfg.num_experts, cfg.experts_per_token
    xf = x.reshape(B * S, D)
    T = xf.shape[0]
    cap = min(T, max(1, math.ceil(T * topk / E * cfg.moe_capacity_factor)))
    combine, probs = _gates(p, xf, cfg)  # [T, E]

    def body(y, ej):
        ep, w_e = ej
        _, order = jax.lax.top_k(w_e, cap)
        xe = xf[order]
        he = ffn_apply(ep, xe, cfg.ffn_activation, policy)
        contrib = jnp.zeros((T, D), he.dtype).at[order].set(
            he * w_e[order, None].astype(he.dtype))
        return y + contrib.astype(jnp.float32), None

    y0 = jnp.zeros((T, D), jnp.float32)
    y, _ = jax.lax.scan(body, y0, (p["experts"], combine.T))
    aux = load_balance_loss(combine, probs, E)
    y = y.reshape(B, S, D).astype(x.dtype)
    if "shared" in p:
        y = y + ffn_apply(p["shared"], x, cfg.ffn_activation, policy)
    return y, aux


def moe_apply(p, x, cfg, ctx: Optional[ParallelCtx] = None, policy=None,
              phase: str = "seq"):
    if ctx is not None and ctx.mesh is not None and ctx.tp > 1:
        if phase == "decode":
            return moe_ep(p, x, cfg, ctx, policy)
        return moe_tp(p, x, cfg, ctx, policy)
    return moe_dense(p, x, cfg, policy)
