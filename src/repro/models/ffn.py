"""Dense FFN variants: SwiGLU / GeGLU / plain GELU MLP."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import apply_linear, make_linear


def init_ffn(key, d_model: int, d_ff: int, activation: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if activation.endswith("_glu"):
        return {
            "w_gate": make_linear(ks[0], d_model, d_ff, dtype=dtype),
            "w_up": make_linear(ks[1], d_model, d_ff, dtype=dtype),
            "w_down": make_linear(ks[2], d_ff, d_model, dtype=dtype),
        }
    return {
        "w_up": make_linear(ks[0], d_model, d_ff, dtype=dtype),
        "w_down": make_linear(ks[1], d_ff, d_model, dtype=dtype),
    }


def _act(name: str):
    return jax.nn.silu if name.startswith("silu") else jax.nn.gelu


def ffn_apply(p, x, activation: str, policy=None):
    if "w_gate" in p:
        g = _act(activation)(apply_linear(p["w_gate"], x, policy))
        u = apply_linear(p["w_up"], x, policy)
        return apply_linear(p["w_down"], g * u, policy)
    h = _act(activation)(apply_linear(p["w_up"], x, policy))
    return apply_linear(p["w_down"], h, policy)
