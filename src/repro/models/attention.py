"""Attention: GQA / MLA / sliding-window, blockwise prefill + flash decode.

Memory-safe by construction:
  * train/prefill use a blockwise online-softmax scan over KV blocks
    (O(S * block) live memory — a 32k prefill never materializes S x S);
  * decode uses flash-decoding: when the KV cache is sequence-sharded over
    the `model` mesh axis (our layout for 32k+ caches), each shard computes a
    partial attention and a log-sum-exp, merged with 3 small collectives.

MLA (MiniCPM3/DeepSeek-style) runs in the *absorbed* form everywhere: scores
and values are computed directly against the compressed KV stream
(kv_lora + rope dims), which is what makes its decode cache tiny.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# The decode online-softmax bodies live in the fused attention template
# (`repro.kernels.attention_template`) — `flash_decode`/`flash_decode_chunk`
# are re-exported here for their historical import path, and every decode
# core below routes through `attend_contiguous` (impl="ref" IS those
# functions, bit-identical; "pallas"/"pallas_interpret" lowers the same
# math through the fused Pallas kernel).
from repro.kernels.attention_template import (  # noqa: F401
    _cache_positions,
    attend_contiguous,
    flash_decode,
    flash_decode_chunk,
)

from .common import apply_linear, apply_rope, make_linear, model_dims


def kv_index_map(H_pad: int, H_true: int, kv: int) -> np.ndarray:
    """Static map q-head slot -> kv head under the group-major layout
    (see Dims): slot j attends kv head j // (H_pad // kv). Uniform by
    construction, so attention always takes the grouped-einsum path."""
    assert H_pad % kv == 0
    return (np.arange(H_pad) // (H_pad // kv)).astype(np.int32)


# ---------------------------------------------------------------------------
# Blockwise attention (train / prefill)
# ---------------------------------------------------------------------------
def blockwise_attention(
    q: jnp.ndarray,            # [B, Sq, H, hd]
    k: jnp.ndarray,            # [B, Skv, kv, hd]
    v: jnp.ndarray,            # [B, Skv, kv, hd_v]
    *,
    kv_map: np.ndarray,        # [H] -> kv head
    causal: bool = True,
    window: int = 0,
    prefix_len: int = 0,       # bidirectional prefix (VLM patches)
    block_kv: int = 1024,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    hd_v = v.shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    bkv = min(block_kv, Skv)
    nb = -(-Skv // bkv)
    Skp = nb * bkv

    kp = jnp.pad(k, ((0, 0), (0, Skp - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Skp - Skv), (0, 0), (0, 0)))
    kp = kp.reshape(B, nb, bkv, k.shape[2], hd).transpose(1, 0, 2, 3, 4)
    vp = vp.reshape(B, nb, bkv, v.shape[2], hd_v).transpose(1, 0, 2, 3, 4)

    qf = q * np.float32(scale).astype(q.dtype)
    q_pos = q_offset + jnp.arange(Sq)
    kvm = jnp.asarray(kv_map)
    k_pos_blocks = jnp.arange(Skp, dtype=jnp.int32).reshape(nb, bkv)

    kv_n = k.shape[2]
    grouped = (H % kv_n == 0) and np.array_equal(
        kv_map, np.arange(H) // (H // kv_n))

    def body(carry, blk):
        m, l, acc = carry
        kj, vj, k_pos = blk  # [B, bkv, kv, hd], ..., [bkv]
        if grouped:
            # grouped einsum: no H-fold materialization of K/V
            g = H // kv_n
            qg = qf.reshape(B, Sq, kv_n, g, hd)
            s = jnp.einsum("bqngd,bknd->bngqk", qg, kj,
                           preferred_element_type=jnp.float32)
            s = s.reshape(B, H, Sq, s.shape[-1])
        else:
            kje = kj[:, :, kvm, :]      # [B, bkv, H, hd]
            s = jnp.einsum("bqhd,bkhd->bhqk", qf, kje,
                           preferred_element_type=jnp.float32)
        valid = (k_pos < Skv)[None, :]
        if causal:
            vis = k_pos[None, :] <= q_pos[:, None]
            if prefix_len > 0:
                vis = vis | (k_pos[None, :] < prefix_len)
            valid = valid & vis
        if window > 0:
            valid = valid & (q_pos[:, None] - k_pos[None, :] < window)
        # additive mask bias instead of two where() passes over the score
        # tensor: masked entries sit at -2e30; the running max is clamped to
        # -1e30, so exp(masked - max) == exp(-1e30) underflows to exactly 0
        # and rows with no valid key yet keep l == 0. No post-exp select,
        # no +/-inf arithmetic -> fewer full-score HBM round trips.
        s = s + jnp.where(valid, 0.0, -2e30)[None, None]

        m_new = jnp.maximum(jnp.maximum(m, s.max(axis=-1)), -1e30)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        if grouped:
            g = H // kv_n
            pg = p.reshape(B, kv_n, g, Sq, p.shape[-1])
            pv = jnp.einsum("bngqk,bknd->bngqd", pg.astype(vj.dtype), vj,
                            preferred_element_type=jnp.float32)
            pv = pv.reshape(B, H, Sq, hd_v)
        else:
            vje = vj[:, :, kvm, :]
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vj.dtype), vje,
                            preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd_v), jnp.float32)
    # flash-attention residency: recompute scores per block in the backward
    # instead of letting scan stack [n_blocks, B, H, Sq, bkv] f32 residuals
    # (measured: the stacked scores dominated train-step HBM traffic).
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                  (kp, vp, k_pos_blocks))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # [B, Sq, H, hd_v]


def cache_insert(cache: jnp.ndarray, new: jnp.ndarray, pos: jnp.ndarray,
                 axis_name: Optional[str] = None, ring_window: int = 0) -> jnp.ndarray:
    """Insert `new` [B, 1, kv, hd] at global position `pos` into a (possibly
    sequence-sharded, possibly ring) cache [B, S_loc, kv, hd]; no-op on
    non-owner shards.

    `pos` is a scalar (whole batch at one position — the one-shot decode
    loop) or [B] per-slot positions (continuous-batching engine). A negative
    per-slot position suppresses the write entirely (idle slot)."""
    S_loc = cache.shape[1]
    shard = jax.lax.axis_index(axis_name) if axis_name else 0
    pos = jnp.asarray(pos, jnp.int32)

    def insert_one(c, n, p, seq_axis):
        slot = (p % ring_window) if ring_window else p
        local = slot - shard * S_loc
        in_range = (p >= 0) & (local >= 0) & (local < S_loc)
        idx = jnp.clip(local, 0, S_loc - 1)
        # select on the 1-token slice, NOT the whole cache (keeps the update
        # O(new) in HBM traffic; a full-cache where() costs a cache-sized
        # select per layer per step)
        old = jax.lax.dynamic_slice_in_dim(c, idx, 1, axis=seq_axis)
        val = jnp.where(in_range, n.astype(c.dtype), old)
        return jax.lax.dynamic_update_slice_in_dim(c, val, idx, axis=seq_axis)

    if pos.ndim == 1:  # per-slot: vmap over the batch dim
        return jax.vmap(lambda c, n, p: insert_one(c, n, p, 0))(cache, new, pos)
    return insert_one(cache, new, pos, 1)


def cache_insert_chunk(cache: jnp.ndarray, new: jnp.ndarray, pos: jnp.ndarray,
                       nvalid: jnp.ndarray,
                       axis_name: Optional[str] = None) -> jnp.ndarray:
    """Insert a ragged chunk `new` [B, c, kv, hd] at per-slot start positions
    ``pos`` [B] into a (possibly sequence-sharded) cache [B, S_loc, kv, hd].

    Slot b writes positions ``pos[b] .. pos[b] + nvalid[b] - 1``; entries at
    chunk index >= nvalid[b] (and whole slots with pos < 0) are routed to an
    out-of-range row index and dropped by the scatter — one scatter per
    layer, no full-cache select.
    """
    B, c = new.shape[0], new.shape[1]
    S_loc = cache.shape[1]
    shard = jax.lax.axis_index(axis_name) if axis_name else 0
    pos = jnp.asarray(pos, jnp.int32)
    nvalid = jnp.asarray(nvalid, jnp.int32)
    j = jnp.arange(c, dtype=jnp.int32)[None, :]
    p = pos[:, None] + j                               # [B, c] global positions
    local = p - shard * S_loc
    ok = ((pos[:, None] >= 0) & (j < nvalid[:, None])
          & (local >= 0) & (local < S_loc))
    idx = jnp.where(ok, local, S_loc)                  # OOB -> dropped
    b_idx = jnp.arange(B, dtype=jnp.int32)[:, None]
    return cache.at[b_idx, idx].set(new.astype(cache.dtype), mode="drop")


def cache_truncate_chunk(cache: jnp.ndarray, start: jnp.ndarray,
                         count: jnp.ndarray, c_max: int,
                         axis_name: Optional[str] = None) -> jnp.ndarray:
    """Zero per-slot positions ``start[b] .. start[b] + count[b] - 1`` of a
    contiguous cache leaf [B, S_loc, ...] — the inverse of
    `cache_insert_chunk`, restoring the zero-initialized state so a later
    re-insert is bit-identical to a straight insert. Used by the
    speculative engine step to un-insert rejected draft tokens; slots with
    ``count == 0`` (or ``start < 0``) are no-ops via the same
    out-of-range-row drop the insert uses. ``c_max`` is the static rewind
    width bound."""
    B, S_loc = cache.shape[0], cache.shape[1]
    shard = jax.lax.axis_index(axis_name) if axis_name else 0
    start = jnp.asarray(start, jnp.int32)
    count = jnp.asarray(count, jnp.int32)
    j = jnp.arange(c_max, dtype=jnp.int32)[None, :]
    local = start[:, None] + j - shard * S_loc
    ok = ((start[:, None] >= 0) & (j < count[:, None])
          & (local >= 0) & (local < S_loc))
    idx = jnp.where(ok, local, S_loc)                  # OOB -> dropped
    b_idx = jnp.arange(B, dtype=jnp.int32)[:, None]
    return cache.at[b_idx, idx].set(jnp.zeros((), cache.dtype), mode="drop")


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------
def init_gqa(key, cfg, dims, dtype=jnp.float32):
    D, hd = cfg.d_model, dims.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": make_linear(ks[0], D, dims.H * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": make_linear(ks[1], D, dims.kv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": make_linear(ks[2], D, dims.kv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": make_linear(ks[3], dims.H * hd, D, dtype=dtype),
    }


def gqa_qkv(p, x, cfg, dims, positions, policy=None):
    """Project + rope. x: [B, S, D] -> q [B,S,H,hd], k/v [B,S,kv,hd]."""
    B, S, _ = x.shape
    hd = dims.hd
    q = apply_linear(p["wq"], x, policy).reshape(B, S, dims.H, hd)
    k = apply_linear(p["wk"], x, policy).reshape(B, S, dims.kv, hd)
    v = apply_linear(p["wv"], x, policy).reshape(B, S, dims.kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attn_train(p, x, cfg, dims, *, policy=None, block_kv=1024,
                   prefix_len=0, window=0):
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = gqa_qkv(p, x, cfg, dims, positions, policy)
    kvm = kv_index_map(dims.H, dims.H_true, dims.kv)
    o = blockwise_attention(q, k, v, kv_map=kvm, causal=True,
                            window=window or cfg.sliding_window,
                            prefix_len=prefix_len, block_kv=block_kv)
    o = o * dims.head_mask[None, None, :, None].astype(o.dtype)
    o = o.reshape(B, S, dims.H * dims.hd)
    return apply_linear(p["wo"], o, policy), (k, v)


def gqa_decode_core(q, k_new, v_new, cache_k, cache_v, pos, *,
                    kv_map, window=0, ring=False, scale=None, axis_name=None,
                    impl="ref"):
    """Insert + attend. q: [B, H, hd]; k/v_new: [B, 1, kv, hd];
    caches [B, S_loc, kv, hd]. Runs inside shard_map when the cache is
    sequence-sharded over `axis_name` (where ``impl`` always resolves to
    the collective-carrying ref path)."""
    cache_k = cache_insert(cache_k, k_new, pos, axis_name, window if ring else 0)
    cache_v = cache_insert(cache_v, v_new, pos, axis_name, window if ring else 0)
    o = attend_contiguous(q, cache_k, cache_v, pos + 1, kv_map=kv_map,
                          axis_name=axis_name, window=window, ring=ring,
                          scale=scale, impl=impl)
    return o, cache_k, cache_v


def gqa_paged_core(q, k_new, v_new, pool, pos, block_tables, *, cache_cfg,
                   scale=None):
    """Paged insert + attend core. q: [B, H, hd]; k/v_new: [B, 1, kv, hd];
    ``pool`` is one layer's page pool. The kv_map is recomputed from the
    OPERAND shapes, not the global dims: under the head-sharded shard_map
    wrap (transformer.py) this core sees each device's local head slice,
    and the group-major layout keeps the local map the same
    ``arange(H) // (H // kv)`` formula at local counts — so quantize,
    scatter-insert and attend all run device-local, and no page ever
    crosses the mesh."""
    from repro.cache import paged_attend, paged_insert

    pool = paged_insert(pool, k_new, v_new, pos, block_tables, cache_cfg)
    kvm = kv_index_map(q.shape[-2], q.shape[-2], k_new.shape[-2])
    lengths = jnp.where(pos >= 0, pos + 1, 0)
    o = paged_attend(q, pool, lengths, block_tables, cache_cfg,
                     kv_map=kvm, scale=scale)
    return o, pool


def gqa_attn_decode_paged(p, x, pool, pos, block_tables, cfg, dims, *,
                          policy=None, cache_cfg=None, core_wrap=None):
    """Paged-cache decode step: x [B, 1, D]; ``pool`` is one layer's page
    pool (repro.cache.pool layout); ``block_tables`` [B, max_pages] int32.

    Each slot's new K/V vector is quantized ONCE at insert (paged-AMS) or
    stored bf16 (paged-bf16); attention walks the block table via the
    configured impl (``ref`` gather-dequantize oracle or the Pallas
    kernel). ``core_wrap(core_fn)`` lets the caller shard_map the
    insert+attend core over local kv-head slices (transformer.py passes a
    wrapper when the pool is head-sharded over the model axis). Returns
    (out, new pool)."""
    import functools
    B = x.shape[0]
    hd = dims.hd
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None] if pos.ndim == 1 else jnp.full((B, 1), pos,
                                                            jnp.int32)
    q, k, v = gqa_qkv(p, x, cfg, dims, positions, policy)
    core = functools.partial(gqa_paged_core, cache_cfg=cache_cfg)
    if core_wrap is not None:
        core = core_wrap(core)
    o, pool = core(q[:, 0], k, v, pool, pos, block_tables)
    o = o * dims.head_mask[None, :, None].astype(o.dtype)
    o = o.reshape(B, 1, dims.H * hd)
    return apply_linear(p["wo"], o, policy), pool


def gqa_attn_decode(p, x, cache_k, cache_v, pos, cfg, dims, *,
                    policy=None, core_wrap=None, window=0, ring=False,
                    attn_impl="ref"):
    """x: [B, 1, D]; caches [B, S_loc, kv, hd]. Returns (out, new caches).

    ``core_wrap(core_fn)`` lets the caller shard_map the insert+attend core
    (transformer.py passes a wrapper when the cache is sequence-sharded).
    ``pos`` is scalar or [B] (per-slot continuous batching)."""
    import functools
    B = x.shape[0]
    hd = dims.hd
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None] if pos.ndim == 1 else jnp.full((B, 1), pos,
                                                            jnp.int32)
    q, k, v = gqa_qkv(p, x, cfg, dims, positions, policy)
    kvm = kv_index_map(dims.H, dims.H_true, dims.kv)
    core = functools.partial(gqa_decode_core, kv_map=kvm,
                             window=window or cfg.sliding_window, ring=ring,
                             impl=attn_impl)
    if core_wrap is not None:
        core = core_wrap(core)
    o, cache_k, cache_v = core(q[:, 0], k, v, cache_k, cache_v, pos)
    o = o * dims.head_mask[None, :, None].astype(o.dtype)
    o = o.reshape(B, 1, dims.H * hd)
    return apply_linear(p["wo"], o, policy), (cache_k, cache_v)


# ---------------------------------------------------------------------------
# Ragged chunked decode (multi-token engine step)
# ---------------------------------------------------------------------------
def chunk_lengths(pos: jnp.ndarray, nvalid: jnp.ndarray, c: int) -> jnp.ndarray:
    """Per-query valid-key counts [B, c] for a chunk inserted at ``pos``:
    query j attends the prefix plus itself (pos + j + 1); rows past nvalid
    (or idle slots, pos < 0) get 0 and flush to exact zeros."""
    pos = jnp.asarray(pos, jnp.int32)
    nvalid = jnp.asarray(nvalid, jnp.int32)
    j = jnp.arange(c, dtype=jnp.int32)[None, :]
    ok = (pos[:, None] >= 0) & (j < nvalid[:, None])
    return jnp.where(ok, pos[:, None] + j + 1, 0)


def gqa_decode_core_chunk(q, k_new, v_new, cache_k, cache_v, pos, nvalid, *,
                          kv_map, scale=None, axis_name=None, impl="ref"):
    """Chunked insert + attend. q: [B, c, H, hd]; k/v_new: [B, c, kv, hd];
    caches [B, S_loc, kv, hd]; pos/nvalid [B]. Keys land first, then every
    query attends with its own length (intra-chunk causal by construction)."""
    cache_k = cache_insert_chunk(cache_k, k_new, pos, nvalid, axis_name)
    cache_v = cache_insert_chunk(cache_v, v_new, pos, nvalid, axis_name)
    lengths = chunk_lengths(pos, nvalid, q.shape[1])
    o = attend_contiguous(q, cache_k, cache_v, lengths, kv_map=kv_map,
                          axis_name=axis_name, scale=scale, impl=impl)
    return o, cache_k, cache_v


def gqa_attn_decode_chunk(p, x, cache_k, cache_v, pos, nvalid, cfg, dims, *,
                          policy=None, core_wrap=None, attn_impl="ref"):
    """Ragged multi-token decode: x [B, c, D], per-slot start positions
    ``pos`` [B] and valid counts ``nvalid`` [B]. Returns (out [B, c, D],
    new caches); rows past a slot's nvalid are exact no-ops."""
    import functools
    B, c, _ = x.shape
    hd = dims.hd
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.maximum(pos[:, None] + jnp.arange(c, dtype=jnp.int32), 0)
    q, k, v = gqa_qkv(p, x, cfg, dims, positions, policy)
    kvm = kv_index_map(dims.H, dims.H_true, dims.kv)
    core = functools.partial(gqa_decode_core_chunk, kv_map=kvm,
                             impl=attn_impl)
    if core_wrap is not None:
        core = core_wrap(core)
    o, cache_k, cache_v = core(q, k, v, cache_k, cache_v, pos, nvalid)
    o = o * dims.head_mask[None, None, :, None].astype(o.dtype)
    o = o.reshape(B, c, dims.H * hd)
    return apply_linear(p["wo"], o, policy), (cache_k, cache_v)


def gqa_paged_core_chunk(q, k_new, v_new, pool, pos, block_tables, nvalid, *,
                         cache_cfg, scale=None):
    """Chunked paged insert + attend core. q: [B, c, H, hd]; k/v_new
    [B, c, kv, hd]. Same local-shape kv_map discipline as
    `gqa_paged_core` — runs unchanged on a device-local head slice under
    the head-sharded shard_map wrap."""
    from repro.cache import paged_attend, paged_insert

    pool = paged_insert(pool, k_new, v_new, pos, block_tables, cache_cfg,
                        nvalid=nvalid)
    kvm = kv_index_map(q.shape[-2], q.shape[-2], k_new.shape[-2])
    lengths = chunk_lengths(pos, nvalid, q.shape[1])
    o = paged_attend(q, pool, lengths, block_tables, cache_cfg,
                     kv_map=kvm, scale=scale)
    return o, pool


def gqa_attn_decode_paged_chunk(p, x, pool, pos, nvalid, block_tables, cfg,
                                dims, *, policy=None, cache_cfg=None,
                                core_wrap=None):
    """Paged ragged decode: x [B, c, D]; the chunk's K/V vectors are packed
    into the layer pool in ONE multi-token scatter per plane
    (`cache.pool.paged_insert` with nvalid), then every query attends the
    block table with its own length through the configured impl.
    ``core_wrap`` as in `gqa_attn_decode_paged`."""
    import functools
    B, c, _ = x.shape
    hd = dims.hd
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.maximum(pos[:, None] + jnp.arange(c, dtype=jnp.int32), 0)
    q, k, v = gqa_qkv(p, x, cfg, dims, positions, policy)
    core = functools.partial(gqa_paged_core_chunk, cache_cfg=cache_cfg)
    if core_wrap is not None:
        core = core_wrap(core)
    o, pool = core(q, k, v, pool, pos, block_tables, nvalid)
    o = o * dims.head_mask[None, None, :, None].astype(o.dtype)
    o = o.reshape(B, c, dims.H * hd)
    return apply_linear(p["wo"], o, policy), pool


# ---------------------------------------------------------------------------
# MLA (absorbed form)
# ---------------------------------------------------------------------------
def init_mla(key, cfg, dims, dtype=jnp.float32):
    D = cfg.d_model
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    H = dims.H
    ks = jax.random.split(key, 7)
    return {
        "wq_a": make_linear(ks[0], D, r_q, dtype=dtype),
        "q_a_norm": jnp.ones((r_q,), dtype),
        "wq_b": make_linear(ks[1], r_q, H * (dn + dr), dtype=dtype),
        "wkv_a": make_linear(ks[2], D, r_kv + dr, dtype=dtype),
        "kv_a_norm": jnp.ones((r_kv,), dtype),
        # absorbed decompression factors, stored per head:
        "w_uk": make_linear(ks[3], r_kv, H * dn, dtype=dtype),   # key-nope
        "w_uv": make_linear(ks[4], r_kv, H * dv, dtype=dtype),   # value
        "wo": make_linear(ks[5], H * dv, D, dtype=dtype),
    }


def _mla_q_eff(p, x, cfg, dims, positions, policy):
    """Absorbed query: q_eff [B, S, H, r_kv + dr]."""
    from .common import rms_norm
    B, S, _ = x.shape
    H = dims.H
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    r_kv = cfg.kv_lora_rank
    cq = rms_norm(apply_linear(p["wq_a"], x, policy), p["q_a_norm"], cfg.norm_eps)
    q = apply_linear(p["wq_b"], cq, policy).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    # absorb: q_nope^T (W_uk per head) -> compressed space
    from .common import materialize_weight
    w_uk = materialize_weight(p["w_uk"], r_kv, q_nope.dtype, policy).reshape(r_kv, H, dn)
    q_c = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk.astype(q_nope.dtype))
    return jnp.concatenate([q_c, q_rope], axis=-1)  # [B,S,H,r_kv+dr]


def _mla_kv_stream(p, x, cfg, positions, policy):
    """Compressed KV stream [B, S, r_kv + dr] (this is the decode cache)."""
    from .common import rms_norm
    dr = cfg.qk_rope_dim
    r_kv = cfg.kv_lora_rank
    ckv = apply_linear(p["wkv_a"], x, policy)
    c, k_rope = ckv[..., :r_kv], ckv[..., r_kv:]
    c = rms_norm(c, p["kv_a_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return jnp.concatenate([c, k_rope], axis=-1)


def _mla_out(p, attn_c, cfg, dims, policy):
    """attn_c: [B, S, H, r_kv] attention-weighted compressed values."""
    B, S, H, r_kv = attn_c.shape
    dv = cfg.v_head_dim
    from .common import materialize_weight
    w_uv = materialize_weight(p["w_uv"], r_kv, attn_c.dtype, policy).reshape(r_kv, H, dv)
    o = jnp.einsum("bshr,rhd->bshd", attn_c, w_uv.astype(attn_c.dtype))
    o = o * dims.head_mask[None, None, :, None].astype(o.dtype)
    return apply_linear(p["wo"], o.reshape(B, S, H * dv), policy)


def mla_attn_train(p, x, cfg, dims, *, policy=None, block_kv=1024, prefix_len=0):
    B, S, _ = x.shape
    r_kv, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    positions = jnp.arange(S)[None, :]
    q_eff = _mla_q_eff(p, x, cfg, dims, positions, policy)
    kv = _mla_kv_stream(p, x, cfg, positions, policy)   # [B, S, r_kv+dr]
    # single shared "kv head" of width r_kv+dr; values = compressed stream r_kv
    k1 = kv[:, :, None, :]
    v1 = kv[:, :, None, :r_kv]
    kvm = np.zeros((dims.H,), np.int32)
    scale = 1.0 / np.sqrt(cfg.qk_nope_dim + dr)
    o_c = blockwise_attention(q_eff, k1, v1, kv_map=kvm, causal=True,
                              prefix_len=prefix_len, block_kv=block_kv,
                              scale=scale)
    out = _mla_out(p, o_c, cfg, dims, policy)
    return out, kv


def mla_decode_core(q_eff, kv_new, cache_kv, pos, *, r_kv, scale,
                    axis_name=None, impl="ref"):
    """cache_kv: [B, S_loc, 1, r_kv+dr]; kv_new: [B, 1, 1, r_kv+dr]. The
    fused path slices values from the SAME compressed stream in-kernel
    (``value_slice=r_kv``) — V costs no extra HBM reads."""
    H = q_eff.shape[1]
    cache_kv = cache_insert(cache_kv, kv_new, pos, axis_name)
    kvm = np.zeros((H,), np.int32)
    o_c = attend_contiguous(q_eff, cache_kv, cache_kv[..., :r_kv], pos + 1,
                            kv_map=kvm, axis_name=axis_name, scale=scale,
                            impl=impl, value_slice=r_kv)
    return o_c, cache_kv


def mla_attn_decode(p, x, cache_kv, pos, cfg, dims, *, policy=None,
                    core_wrap=None, attn_impl="ref"):
    """cache_kv: [B, S_loc, 1, r_kv+dr] compressed cache; pos scalar or [B]."""
    import functools
    r_kv, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None] if pos.ndim == 1 else jnp.full((B, 1), pos,
                                                            jnp.int32)
    q_eff = _mla_q_eff(p, x, cfg, dims, positions, policy)[:, 0]  # [B,H,r+dr]
    kv = _mla_kv_stream(p, x, cfg, positions, policy)             # [B,1,r+dr]
    scale = 1.0 / np.sqrt(cfg.qk_nope_dim + dr)
    core = functools.partial(mla_decode_core, r_kv=r_kv, scale=scale,
                             impl=attn_impl)
    if core_wrap is not None:
        core = core_wrap(core)
    o_c, cache_kv = core(q_eff, kv[:, :, None, :], cache_kv, pos)
    out = _mla_out(p, o_c[:, None], cfg, dims, policy)
    return out, cache_kv


def mla_decode_core_chunk(q_eff, kv_new, cache_kv, pos, nvalid, *, r_kv,
                          scale, axis_name=None, impl="ref"):
    """Chunked absorbed-MLA core. q_eff [B, c, H, r_kv+dr]; kv_new
    [B, c, 1, r_kv+dr]; cache_kv [B, S_loc, 1, r_kv+dr]."""
    cache_kv = cache_insert_chunk(cache_kv, kv_new, pos, nvalid, axis_name)
    kvm = np.zeros((q_eff.shape[2],), np.int32)
    lengths = chunk_lengths(pos, nvalid, q_eff.shape[1])
    o_c = attend_contiguous(q_eff, cache_kv, cache_kv[..., :r_kv], lengths,
                            kv_map=kvm, axis_name=axis_name, scale=scale,
                            impl=impl, value_slice=r_kv)
    return o_c, cache_kv


def mla_attn_decode_chunk(p, x, cache_kv, pos, nvalid, cfg, dims, *,
                          policy=None, core_wrap=None, attn_impl="ref"):
    """Ragged multi-token MLA decode: x [B, c, D]; same contract as
    `gqa_attn_decode_chunk` on the compressed KV stream."""
    import functools
    r_kv, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    B, c, _ = x.shape
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.maximum(pos[:, None] + jnp.arange(c, dtype=jnp.int32), 0)
    q_eff = _mla_q_eff(p, x, cfg, dims, positions, policy)   # [B, c, H, r+dr]
    kv = _mla_kv_stream(p, x, cfg, positions, policy)        # [B, c, r+dr]
    scale = 1.0 / np.sqrt(cfg.qk_nope_dim + dr)
    core = functools.partial(mla_decode_core_chunk, r_kv=r_kv, scale=scale,
                             impl=attn_impl)
    if core_wrap is not None:
        core = core_wrap(core)
    o_c, cache_kv = core(q_eff, kv[:, :, None, :], cache_kv, pos, nvalid)
    out = _mla_out(p, o_c, cfg, dims, policy)
    return out, cache_kv
