"""Serving observability: metrics registry, trace spans, roofline cost.

Three layers, wired through the serving stack (see docs/observability.md):

  * `obs.metrics`  — labelled counters/gauges/histograms with Prometheus
    text exposition and JSONL snapshots; `ServeEngine.stats()` is computed
    from this registry;
  * `obs.trace`    — per-request lifecycle spans + per-tick device-step
    spans as Chrome trace-event JSON (load in Perfetto);
  * `obs.cost`     — analytic HBM-byte / FLOP floors per engine-step
    signature, accumulated per tick and per request, plus the compiled
    step's parsed HLO cost as the achieved side.

`ObsConfig(enabled=False)` swaps in no-op instruments end to end —
telemetry can never perturb the measured system (asserted by the bench
``--obs-check`` mode).
"""

from repro.obs.config import ObsConfig
from repro.obs.cost import (
    StepCostModel,
    attribution,
    build_cost_model,
    hlo_step_cost,
    kv_vector_bytes_floor,
    kv_vector_bytes_ideal,
)
from repro.obs.metrics import (
    NULL_REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    parse_prom,
    ticker_line,
)
from repro.obs.trace import TraceRecorder, validate_events

__all__ = [
    "ObsConfig", "MetricsRegistry", "NULL_REGISTRY",
    "Counter", "Gauge", "Histogram", "parse_prom", "ticker_line",
    "TraceRecorder", "validate_events",
    "StepCostModel", "build_cost_model", "attribution", "hlo_step_cost",
    "kv_vector_bytes_floor", "kv_vector_bytes_ideal",
]
