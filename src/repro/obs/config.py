"""Observability configuration for the serving engine.

One `ObsConfig` selects how much telemetry the engine records, in the same
frozen-dataclass style as `cache.CacheConfig`:

  * ``enabled=True``  (default) — the engine creates a live
    `MetricsRegistry` and emits counters/gauges/histograms on every tick;
    `ServeEngine.stats()` is computed from the registry. Recording is a
    handful of float adds per tick on pre-resolved instruments, so the
    measured system is not perturbed (asserted by the bench
    ``--obs-check`` run and tests/test_obs.py).
  * ``enabled=False`` — every instrument is the shared no-op
    `NULL_REGISTRY` child: call sites stay branch-free and accumulated
    telemetry reads as zero. Pure-state stats (kv bytes/token, queue
    depth) remain real.
  * ``trace=True`` — additionally record per-request lifecycle spans and
    per-tick device-step spans (`obs.trace.TraceRecorder`). Device-step
    spans are timed via ``jax.block_until_ready``, which SERIALIZES
    dispatch — tracing is for inspection runs, not benchmark rows.
  * ``cost=True`` (default) — attach the analytic roofline cost model
    (`obs.cost.StepCostModel`) and accumulate per-tick / per-request
    floor-vs-achieved HBM byte accounting.
  * ``jax_profile_ticks=N`` — capture the first N served ticks with
    ``jax.profiler`` into ``jax_profile_dir`` (XLA-level trace; loads in
    TensorBoard/Perfetto). 0 disables.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """How much telemetry the serving engine records."""

    enabled: bool = True        # master switch: False -> no-op instruments
    trace: bool = False         # record lifecycle + device-step spans
    cost: bool = True           # roofline floor/achieved byte accounting
    jax_profile_ticks: int = 0  # capture the first N served ticks
    jax_profile_dir: str = "/tmp/repro_jax_trace"

    def __post_init__(self):
        if self.jax_profile_ticks < 0:
            raise ValueError("jax_profile_ticks must be >= 0")

    @property
    def trace_on(self) -> bool:
        return self.enabled and self.trace

    @property
    def cost_on(self) -> bool:
        return self.enabled and self.cost
