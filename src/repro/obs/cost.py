"""Roofline-attributed serving cost: analytic floors per engine tick.

The paper's decode speedup is MEMORY-bound — fewer HBM bytes per emitted
token, not fewer FLOPs — so the honest continuously-measured metric is
"bytes the engine moved vs the analytic floor for the work it did". This
module computes, per engine-step signature (cache mode x chunk x
speculate_k, see `launch.steps.engine_step_signature`):

  * a `StepCostModel` of analytic per-token costs built from
    `analysis.roofline.param_count` (same MODEL_FLOPS convention: 2 x
    active params per token) plus the KV floors below;
  * per-tick floor HBM bytes / FLOPs for the tokens the tick actually fed
    and the causal positions it attended (the engine accumulates these
    into the registry and onto each `Request`);
  * optionally, the ACHIEVED per-tick cost of the compiled step program
    (`hlo_step_cost`: lower + compile the jitted step, parse with
    `analysis.hlo_cost.module_cost`).

Two KV floors, deliberately distinct (docs/observability.md discusses how
to read the ratio between them):

  * `kv_vector_bytes_floor` — the FORMAT floor: bytes one packed K or V
    vector occupies under the AMS page layout (4-bit hi-code plane packed
    two per byte, shared-LSB bitplane in 32-bit words, one f32 scale per
    (token, head) vector), with the head dim padded to lcm(k, 2). This is
    derived here from the scheme parameters, INDEPENDENTLY of
    `repro.cache` — tests cross-check it against the pool's measured
    `pool_bytes_per_token`, so layout drift in either trips a test.
  * `kv_vector_bytes_ideal` — the PAPER floor: head_dim x effective_bits
    / 8 + the f32 scale, ignoring padding and word granularity. The
    format floor converges to it as head_dim grows (equal at
    head_dim = 128 for fp4.25-e2m2); at the reduced test dims the gap is
    the measured padding overhead, reported as ``kv_vs_ideal_floor``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.analysis.roofline import HBM_BW, PEAK_FLOPS, param_count
from repro.core.formats import SCHEMES, AMSFormat, get_scheme


# ------------------------------------------------------------- KV floors
def kv_vector_bytes_floor(hd: int, scheme: AMSFormat) -> int:
    """FORMAT floor: bytes per packed K or V vector of `hd` elements.

    hi-code plane: (total_bits - 1) bits per element, byte-packed over the
    head dim padded to lcm(k, 2) (4-bit codes -> two per byte); LSB plane:
    one shared bit per k-group, in 32-bit words; scale: one f32 per
    vector. Must equal the pool layout's `cache.pool.pool_bytes_per_token`
    per vector — asserted by tests/test_obs.py.
    """
    unit = math.lcm(scheme.k, 2)
    hd_p = -(-hd // unit) * unit
    hi = -(-hd_p * (scheme.base.total_bits - 1) // 8)
    lsb = 4 * (-(-(hd_p // scheme.k) // 32))
    return hi + lsb + 4


def kv_vector_bytes_ideal(hd: int, scheme: AMSFormat) -> float:
    """PAPER floor: effective_bits per element + the f32 scale, no padding
    or word granularity. effective_bits = (total_bits - 1) + 1/k."""
    return hd * scheme.effective_bits / 8.0 + 4.0


# ------------------------------------------------------------ cost model
@dataclasses.dataclass
class StepCostModel:
    """Analytic per-token costs of one engine-step signature. All
    weight/KV byte fields are PER-DEVICE: ``weight_bytes`` divides by tp,
    and the KV floors divide by the head-shard count when the paged pool
    is head-sharded over the model axis (`build_cost_model` ``kv_shards``),
    matching the per-device achieved bytes the engine accounts."""

    signature: Dict[str, object]
    weight_bytes: float            # packed weight working set (read per tick)
    flops_per_token: float         # 2 x active params (roofline convention)
    attn_flops_per_pos: float      # QK + AV per (query token, key position)
    kv_bytes_per_token: float      # FORMAT floor, K+V, all layers
    kv_ideal_bytes_per_token: float  # PAPER floor, K+V, all layers
    kv_bf16_bytes_per_token: float   # the bf16 baseline the paper divides by
    # f32 K+V gather round-trip per dequantized position (write + read of
    # the dense views the ref impl materializes; 0 for bf16 caches)
    kv_dequant_bytes_per_token: float = 0.0

    def tick_floor_bytes(self, tokens_fed: int, positions_read: int) -> float:
        """Floor HBM traffic of one tick: every weight byte once, plus one
        KV write per fed token and one KV read per attended position."""
        return (self.weight_bytes
                + (tokens_fed + positions_read) * self.kv_bytes_per_token)

    def tick_floor_flops(self, tokens_fed: int, positions_read: int) -> float:
        return (self.flops_per_token * tokens_fed
                + self.attn_flops_per_pos * positions_read)

    def step_time_floor_s(self, tokens_fed: int, positions_read: int) -> float:
        """Roofline time floor of one tick on the reference device
        (`analysis.roofline` PEAK_FLOPS / HBM_BW constants)."""
        return max(self.tick_floor_bytes(tokens_fed, positions_read) / HBM_BW,
                   self.tick_floor_flops(tokens_fed, positions_read)
                   / PEAK_FLOPS)

    # ------------------------------------------------ achieved KV bytes
    def achieved_kv_read_positions(self, i: int, n: int, *,
                                   cache_kind: str = "contiguous",
                                   impl: str = "ref", capacity: int = 0,
                                   page_size: int = 0,
                                   max_pages: int = 0) -> int:
        """Cache positions the implementation READS while appending n
        tokens to a slot already holding i: the dense capacity for a
        contiguous cache, the full block-table row for the paged ref
        gather, and the causally-touched whole pages for the fused
        template (which length-masks inside the page)."""
        if cache_kind == "contiguous" or not page_size:
            return n * capacity
        if impl == "ref":
            return n * max_pages * page_size
        return sum(-(-(i + j + 1) // page_size) * page_size
                   for j in range(n))

    def achieved_kv_bytes(self, i: int, n: int, *,
                          cache_kind: str = "contiguous", impl: str = "ref",
                          capacity: int = 0, page_size: int = 0,
                          max_pages: int = 0,
                          bytes_per_token: Optional[float] = None) -> float:
        """Bytes the cache implementation moves for that same append: one
        pool-layout write per fed token plus the read width above — and,
        for the REF impl of a quantized cache only, the gather-dequantize
        ROUND TRIP (it materializes dense f32 K/V views in HBM and reads
        them back; `kv_dequant_bytes_per_token` per gathered position).
        The fused template restores packed planes in VREGs, so its branch
        carries no dequant term — `kv_vs_floor` then reflects exactly the
        causal-page padding, which the bench asserts
        (`benchmarks/bench_kernel_speedup.py` attention rows)."""
        bpt = (self.kv_bytes_per_token if bytes_per_token is None
               else bytes_per_token)
        reads = self.achieved_kv_read_positions(
            i, n, cache_kind=cache_kind, impl=impl, capacity=capacity,
            page_size=page_size, max_pages=max_pages)
        out = (n + reads) * bpt
        if (page_size and impl == "ref"
                and self.kv_dequant_bytes_per_token):
            out += reads * self.kv_dequant_bytes_per_token
        return out


def build_cost_model(cfg, scheme: str, cache_cfg=None, *,
                     kv: Optional[int] = None, hd: Optional[int] = None,
                     tp: int = 1, kv_shards: int = 1,
                     signature: Optional[Dict[str, object]] = None,
                     ) -> StepCostModel:
    """Cost model for one engine configuration. ``scheme`` is the WEIGHT
    scheme ("fp16" = unquantized bf16 weights); ``cache_cfg`` selects the
    KV floors (None / contiguous / paged_bf16 -> bf16 KV). ``kv``/``hd``
    override the config's KV-head geometry with the engine's served dims
    (`models.model_dims` pads heads under tensor parallelism).

    ``kv_shards`` makes the KV floors PER-DEVICE on a head-sharded serving
    mesh: with kv heads split over a model axis of size tp, each device
    writes/reads kv/tp heads per token, so every format/ideal/bf16/dequant
    floor divides by it. The engine passes its own head-sharding rule
    (tp when the paged pool splits, else 1), matching the per-device
    achieved bytes it measures — `kv_vs_floor` stays a ratio of like
    quantities (1.0-ish) instead of over-reporting tp x traffic."""
    pc = param_count(cfg)
    wbits = SCHEMES[scheme].effective_bits if scheme in SCHEMES else 16.0
    kv = cfg.num_kv_heads if kv is None else kv
    hd = cfg.head_dim if hd is None else hd
    if kv_shards > 1:
        if kv % kv_shards:
            raise ValueError(f"kv_shards={kv_shards} must divide kv={kv}")
        kv //= kv_shards
    bf16_tok = 2 * kv * (2 * hd)
    dequant = 0.0
    if cache_cfg is not None and getattr(cache_cfg, "quantized", False):
        fmt = get_scheme(cache_cfg.kv_scheme)
        kv_tok = 2 * kv * kv_vector_bytes_floor(hd, fmt)
        kv_ideal = 2 * kv * kv_vector_bytes_ideal(hd, fmt)
        # the ref gather-dequantize writes + reads back dense f32 K and V
        # views per gathered position (2 vectors x hd x 4 bytes x 2 trips)
        dequant = 2 * kv * hd * 4 * 2
    else:
        kv_tok = float(bf16_tok)
        kv_ideal = float(bf16_tok)
    return StepCostModel(
        signature=dict(signature or {}),
        weight_bytes=pc["total"] * wbits / 8.0 / tp,
        flops_per_token=2.0 * pc["active"],
        attn_flops_per_pos=4.0 * cfg.num_heads * hd,
        kv_bytes_per_token=cfg.num_layers * kv_tok,
        kv_ideal_bytes_per_token=cfg.num_layers * kv_ideal,
        kv_bf16_bytes_per_token=cfg.num_layers * float(bf16_tok),
        kv_dequant_bytes_per_token=cfg.num_layers * float(dequant),
    )


# --------------------------------------------------- achieved (compiled)
def hlo_step_cost(jitted, arg_shapes: Dict[str, object]) -> Dict[str, float]:
    """Per-tick cost of the COMPILED engine step: lower the jitted step at
    its serving shapes, compile, and parse the optimized HLO with
    `analysis.hlo_cost.module_cost`. This is the achieved side of the
    roofline — what the program actually moves, XLA copies included —
    against which `StepCostModel.tick_floor_*` is the floor. Compiling
    costs seconds; bench exposes it behind ``--hlo-cost``."""
    from repro.analysis.hlo_cost import module_cost
    txt = jitted.lower(*arg_shapes.values()).compile().as_text()
    c = module_cost(txt)
    return {"hlo_flops_per_tick": float(c.flops),
            "hlo_hbm_bytes_per_tick": float(c.hbm_bytes)}


def attribution(eng, hlo: bool = False) -> Dict[str, object]:
    """Run-level achieved-vs-floor report from an engine's registry.

    ``kv_achieved_vs_floor`` is the KV READ/WRITE AMPLIFICATION: bytes the
    cache implementation actually touches (dense-width gathers included)
    over the causal floor — ~1 for the Pallas paged kernel, capacity /
    mean_len for the contiguous cache. With ``hlo=True`` also compiles
    the step and reports its parsed per-tick cost."""
    m = eng.metrics
    cm = eng.cost_model
    measured = float(eng.kv_bytes_per_token())
    ticks = m.value("serve_device_steps_total")
    floor_b = m.value("serve_floor_hbm_bytes_total")
    kv_floor = m.value("serve_kv_floor_bytes_total")
    kv_ach = m.value("serve_kv_achieved_bytes_total")
    out: Dict[str, object] = {
        "signature": dict(cm.signature),
        "kv_bytes_per_token": measured,
        "kv_bytes_per_token_floor": cm.kv_bytes_per_token,
        "kv_bytes_per_token_ideal": cm.kv_ideal_bytes_per_token,
        "kv_floor_ratio": measured / cm.kv_bytes_per_token,
        "kv_vs_ideal_floor": measured / cm.kv_ideal_bytes_per_token,
        "served_ticks": ticks,
        "floor_hbm_bytes_total": floor_b,
        "floor_flops_total": m.value("serve_floor_flops_total"),
        "kv_floor_bytes_total": kv_floor,
        "kv_achieved_bytes_total": kv_ach,
        "kv_achieved_vs_floor": kv_ach / kv_floor if kv_floor else 0.0,
        "floor_hbm_bytes_per_tick": floor_b / ticks if ticks else 0.0,
    }
    if hlo:
        out.update(hlo_step_cost(eng._step, eng._step_shapes))
        if ticks:
            out["hlo_hbm_vs_floor"] = (out["hlo_hbm_bytes_per_tick"]
                                       / out["floor_hbm_bytes_per_tick"])
    return out
