"""Per-request lifecycle spans as Chrome trace-event JSON.

The engine records one span tree per request on its own trace "thread"
(tid = rid + 1):

    request
      queued                submit -> admitted
      prefill               admitted -> first generated token
      decode                first token -> finish
      finished  (instant)

plus the engine thread (tid 0), which carries per-tick spans::

    tick
      admit                 host-side admission + page allocation
      device_step           the jitted ragged step, timed to completion
                            via jax.block_until_ready (tracing therefore
                            serializes dispatch — inspection runs only)

The export (`save` / `chrome`) is the Chrome trace-event format: load the
JSON at https://ui.perfetto.dev or chrome://tracing. ``B``/``E`` events
require strict LIFO nesting per thread — `end` enforces it eagerly (a
mis-nested span raises at the recording site, not at viewing time), and
`validate_events` re-checks a finished event stream structurally, which is
what tests/test_obs.py runs against random traffic.

Timestamps come from one ``time.perf_counter_ns`` clock, exported in
microseconds relative to recorder construction — monotonic by
construction, which `validate_events` also asserts.

A disabled recorder (``TraceRecorder(enabled=False)``) early-returns from
every method: the zero-perturbation guarantee of `ObsConfig` again
reduces to a no-op call per event.
"""

from __future__ import annotations

import json
from time import perf_counter_ns
from typing import Dict, List, Optional, Tuple

PID = 1          # single-process engine: one trace process


class TraceRecorder:
    """Span recorder with eager nesting validation (module docstring)."""

    def __init__(self, enabled: bool = True, clock=perf_counter_ns):
        self.enabled = enabled
        self._clock = clock
        self._t0 = clock() if enabled else 0
        self._events: List[dict] = []
        self._stacks: Dict[int, List[str]] = {}
        self._named: Dict[int, str] = {}

    def _ts(self) -> float:
        return (self._clock() - self._t0) / 1e3    # ns -> us

    # ------------------------------------------------------------ recording
    def thread(self, tid: int, name: str) -> None:
        """Name a trace thread (one per request, plus tid 0 = engine)."""
        if not self.enabled or self._named.get(tid) == name:
            return
        self._named[tid] = name
        self._events.append({"ph": "M", "pid": PID, "tid": tid, "ts": 0,
                             "name": "thread_name", "args": {"name": name}})

    def begin(self, tid: int, name: str, args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        ev = {"ph": "B", "pid": PID, "tid": tid, "name": name,
              "ts": self._ts()}
        if args:
            ev["args"] = args
        self._events.append(ev)
        self._stacks.setdefault(tid, []).append(name)

    def end(self, tid: int, name: str, args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        stack = self._stacks.get(tid, [])
        if not stack or stack[-1] != name:
            raise RuntimeError(
                f"span nesting violated on tid {tid}: end({name!r}) but "
                f"open spans are {stack}")
        stack.pop()
        ev = {"ph": "E", "pid": PID, "tid": tid, "name": name,
              "ts": self._ts()}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def instant(self, tid: int, name: str, args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        ev = {"ph": "i", "s": "t", "pid": PID, "tid": tid, "name": name,
              "ts": self._ts()}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def counter(self, name: str, values: Dict[str, float],
                tid: int = 0) -> None:
        """Counter track (rendered as a stacked area chart in Perfetto)."""
        if not self.enabled:
            return
        self._events.append({"ph": "C", "pid": PID, "tid": tid, "name": name,
                             "ts": self._ts(), "args": dict(values)})

    # -------------------------------------------------------------- queries
    def open_spans(self) -> Dict[int, List[str]]:
        """Still-open spans per tid — empty when every span closed (the
        lifecycle invariant the tests assert after a drained workload)."""
        return {tid: list(s) for tid, s in self._stacks.items() if s}

    def events(self) -> List[dict]:
        return list(self._events)

    # --------------------------------------------------------------- export
    def chrome(self) -> dict:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome(), f)


def validate_events(events: List[dict]) -> Dict[int, List[Tuple[str, float, float, int]]]:
    """Structural check of a finished trace-event stream: per-tid LIFO
    B/E pairing, no dangling opens, and non-decreasing timestamps per tid.
    Returns the reconstructed spans {tid: [(name, ts_begin, ts_end,
    depth)]}; raises AssertionError on any violation."""
    stacks: Dict[int, List[Tuple[str, float]]] = {}
    last_ts: Dict[int, float] = {}
    spans: Dict[int, List[Tuple[str, float, float, int]]] = {}
    for ev in events:
        tid = ev["tid"]
        ph = ev["ph"]
        if ph == "M":
            continue
        ts = ev["ts"]
        assert ts >= last_ts.get(tid, 0.0), (
            f"tid {tid}: timestamp went backwards ({ts} < {last_ts[tid]})")
        last_ts[tid] = ts
        if ph == "B":
            stacks.setdefault(tid, []).append((ev["name"], ts))
        elif ph == "E":
            stack = stacks.get(tid, [])
            assert stack, f"tid {tid}: E {ev['name']!r} with no open span"
            name, ts_b = stack.pop()
            assert name == ev["name"], (
                f"tid {tid}: E {ev['name']!r} does not match open span "
                f"{name!r} (mis-nesting)")
            spans.setdefault(tid, []).append((name, ts_b, ts, len(stack)))
        elif ph not in ("i", "C"):
            raise AssertionError(f"unexpected phase {ph!r}")
    dangling = {tid: [n for n, _ in s] for tid, s in stacks.items() if s}
    assert not dangling, f"spans never closed: {dangling}"
    return spans
