"""Serving metrics registry: labelled counters / gauges / histograms.

This is the engine's single accounting substrate. Three design constraints
shape it (docs/observability.md):

  * NEAR-ZERO-COST RECORDING — an instrument is resolved once (e.g. in
    ``ServeEngine.__init__``) and recording is a plain float add on a
    ``__slots__`` attribute. No label-dict hashing, no locks, no string
    formatting on the hot path.
  * STATS ARE DERIVED, NOT PARALLEL — ``ServeEngine.stats()`` is computed
    FROM the registry. Histograms therefore retain their raw observations
    in insertion order (``keep_raw``), so the legacy percentile math
    (numpy over the exact same array) stays bit-identical to the
    pre-registry implementation (pinned by tests/test_obs.py).
  * EXPORT IS A SIDE CHANNEL — Prometheus text exposition
    (`exposition` / `write_prom`) and JSONL snapshots (`write_jsonl`) for
    diffable CI artifacts; `parse_prom` round-trips the exposition for
    tests and offline diffing.

A disabled registry (``MetricsRegistry(enabled=False)``, or the module
singleton `NULL_REGISTRY`) hands out one shared no-op instrument, so call
sites never branch on whether observability is on — the ``ObsConfig``
guarantee that telemetry cannot perturb the measured system reduces to
"a no-op method call per event".

Single-threaded by design, like the engine's tick loop: no locks. The
registry is per-engine, not a process global, so two engines (e.g. the
bench's fp16 vs AMS runs) never share counters.
"""

from __future__ import annotations

import json
import re
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

# bucket defaults: engine ticks are ~ms on CPU, ~100us on device
TIME_BUCKETS = (1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 5e-2, 0.1, 0.5, 1.0, 5.0)
COUNT_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0)


class _NullInstrument:
    """Shared no-op stand-in for every instrument of a disabled registry."""

    __slots__ = ()
    value = 0.0
    total = 0.0
    count = 0
    sum = 0.0

    def labels(self, **kv):
        return self

    def inc(self, n: float = 1.0):
        pass

    def dec(self, n: float = 1.0):
        pass

    def set(self, v: float):
        pass

    def observe(self, v: float):
        pass

    def raw_values(self) -> List[float]:
        return []


NULL_INSTRUMENT = _NullInstrument()


class _CounterChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0):
        self.value += n


class _GaugeChild:
    __slots__ = ("_value", "fn")

    def __init__(self, fn: Optional[Callable[[], float]] = None):
        self._value = 0.0
        self.fn = fn

    def set(self, v: float):
        self._value = float(v)

    def inc(self, n: float = 1.0):
        self._value += n

    def dec(self, n: float = 1.0):
        self._value -= n

    @property
    def value(self) -> float:
        return float(self.fn()) if self.fn is not None else self._value


class _HistogramChild:
    __slots__ = ("buckets", "counts", "sum", "count", "raw")

    def __init__(self, buckets: Tuple[float, ...], keep_raw: bool):
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)   # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0
        # insertion-order raw observations — the bit-identical stats() path
        self.raw: Optional[List[float]] = [] if keep_raw else None

    def observe(self, v: float):
        v = float(v)
        self.sum += v
        self.count += 1
        if self.raw is not None:
            self.raw.append(v)
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def raw_values(self) -> List[float]:
        return self.raw if self.raw is not None else []


class _Family:
    """One named metric with zero or more labelled children."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "", labelnames: Iterable[str] = ()):
        if not re.fullmatch(r"[a-zA-Z_:][a-zA-Z0-9_:]*", name):
            raise ValueError(f"invalid metric name {name!r}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._children: Dict[Tuple[str, ...], object] = {}

    def _make_child(self):
        raise NotImplementedError

    def labels(self, **kv):
        if set(kv) != set(self.labelnames):
            raise ValueError(f"{self.name}: expected labels "
                             f"{self.labelnames}, got {tuple(kv)}")
        key = tuple(str(kv[n]) for n in self.labelnames)
        child = self._children.get(key)
        if child is None:
            child = self._children[key] = self._make_child()
        return child

    def _default(self):
        """The unlabelled child — only valid for label-less families."""
        if self.labelnames:
            raise ValueError(f"{self.name} requires labels {self.labelnames}")
        return self.labels()

    def children(self) -> Iterable[Tuple[Tuple[str, ...], object]]:
        return self._children.items()

    def reset(self):
        for child in self._children.values():
            if isinstance(child, _CounterChild):
                child.value = 0.0
            elif isinstance(child, _GaugeChild):
                child._value = 0.0      # callback gauges keep their fn
            elif isinstance(child, _HistogramChild):
                child.counts = [0] * (len(child.buckets) + 1)
                child.sum = 0.0
                child.count = 0
                if child.raw is not None:
                    child.raw = []


class Counter(_Family):
    kind = "counter"

    def _make_child(self):
        return _CounterChild()

    def inc(self, n: float = 1.0):
        self._default().inc(n)

    @property
    def value(self) -> float:
        return self._default().value

    @property
    def total(self) -> float:
        """Sum across every labelled child."""
        return sum(c.value for c in self._children.values())


class Gauge(_Family):
    kind = "gauge"

    def __init__(self, name, help="", labelnames=(),
                 fn: Optional[Callable[[], float]] = None):
        super().__init__(name, help, labelnames)
        if fn is not None and self.labelnames:
            raise ValueError("callback gauges cannot have labels")
        self._fn = fn

    def _make_child(self):
        return _GaugeChild(self._fn)

    def set(self, v: float):
        self._default().set(v)

    def inc(self, n: float = 1.0):
        self._default().inc(n)

    def dec(self, n: float = 1.0):
        self._default().dec(n)

    @property
    def value(self) -> float:
        return self._default().value


class Histogram(_Family):
    kind = "histogram"

    def __init__(self, name, help="", labelnames=(),
                 buckets: Tuple[float, ...] = TIME_BUCKETS,
                 keep_raw: bool = True):
        super().__init__(name, help, labelnames)
        bl = tuple(sorted(float(b) for b in buckets))
        if len(set(bl)) != len(bl) or not bl:
            raise ValueError(f"{name}: buckets must be non-empty and unique")
        self.buckets = bl
        self.keep_raw = keep_raw

    def _make_child(self):
        return _HistogramChild(self.buckets, self.keep_raw)

    def observe(self, v: float):
        self._default().observe(v)

    def raw_values(self) -> List[float]:
        return self._default().raw_values()

    @property
    def count(self) -> int:
        return self._default().count

    @property
    def sum(self) -> float:
        return self._default().sum


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _esc(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labelstr(names: Tuple[str, ...], values: Tuple[str, ...],
              extra: Tuple[Tuple[str, str], ...] = ()) -> str:
    pairs = [f'{n}="{_esc(v)}"' for n, v in zip(names, values)]
    pairs += [f'{n}="{_esc(v)}"' for n, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


class MetricsRegistry:
    """Name -> metric family; the factory call sites register through.

    ``counter``/``gauge``/``histogram`` are get-or-create: registering the
    same name twice returns the SAME family (type/labels must match), so
    subsystems sharing one engine registry (scheduler, allocator, drafter)
    can resolve their instruments independently.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._families: Dict[str, _Family] = {}

    def _get(self, cls, name, help, labelnames, **kw):
        if not self.enabled:
            return NULL_INSTRUMENT
        fam = self._families.get(name)
        if fam is None:
            fam = self._families[name] = cls(name, help, labelnames, **kw)
        elif type(fam) is not cls or fam.labelnames != tuple(labelnames):
            raise ValueError(
                f"metric {name!r} already registered as {fam.kind} with "
                f"labels {fam.labelnames}")
        return fam

    def counter(self, name: str, help: str = "",
                labelnames: Iterable[str] = ()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Iterable[str] = (),
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        return self._get(Gauge, name, help, labelnames, fn=fn)

    def histogram(self, name: str, help: str = "",
                  labelnames: Iterable[str] = (),
                  buckets: Tuple[float, ...] = TIME_BUCKETS,
                  keep_raw: bool = True) -> Histogram:
        return self._get(Histogram, name, help, labelnames,
                         buckets=buckets, keep_raw=keep_raw)

    # -------------------------------------------------------------- queries
    def value(self, name: str, **labels) -> float:
        """Current value of a counter/gauge child (0.0 when absent) — the
        lookup API the live ticker and ad-hoc readers use. For histograms
        returns the observation count."""
        fam = self._families.get(name)
        if fam is None:
            return 0.0
        key = tuple(str(labels[n]) for n in fam.labelnames)
        child = fam._children.get(key)
        if child is None:
            return 0.0
        if isinstance(child, _HistogramChild):
            return float(child.count)
        return float(child.value)

    def collect(self) -> List[_Family]:
        return [self._families[n] for n in sorted(self._families)]

    def reset(self) -> None:
        """Zero every child (counters/gauges/histograms); registrations and
        callback gauges survive — `ServeEngine.reset_metrics` uses this
        after jit warmup."""
        for fam in self._families.values():
            fam.reset()

    # --------------------------------------------------------------- export
    def exposition(self) -> str:
        """Prometheus text exposition format, version 0.0.4."""
        lines: List[str] = []
        for fam in self.collect():
            if fam.help:
                lines.append(f"# HELP {fam.name} {_esc(fam.help)}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            if not fam._children and not fam.labelnames:
                fam._default()          # materialize the unlabelled child
            for key, child in sorted(fam.children()):
                if isinstance(child, _HistogramChild):
                    cum = 0
                    for b, c in zip(child.buckets, child.counts):
                        cum += c
                        ls = _labelstr(fam.labelnames, key,
                                       (("le", _fmt(b)),))
                        lines.append(f"{fam.name}_bucket{ls} {cum}")
                    ls = _labelstr(fam.labelnames, key, (("le", "+Inf"),))
                    lines.append(f"{fam.name}_bucket{ls} {child.count}")
                    ls = _labelstr(fam.labelnames, key)
                    lines.append(f"{fam.name}_sum{ls} {_fmt(child.sum)}")
                    lines.append(f"{fam.name}_count{ls} {child.count}")
                else:
                    ls = _labelstr(fam.labelnames, key)
                    lines.append(f"{fam.name}{ls} {_fmt(child.value)}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict[str, dict]:
        """JSON-friendly dump of every family and child."""
        out: Dict[str, dict] = {}
        for fam in self.collect():
            rows = []
            for key, child in sorted(fam.children()):
                row: Dict[str, object] = {
                    "labels": dict(zip(fam.labelnames, key))}
                if isinstance(child, _HistogramChild):
                    row.update(sum=child.sum, count=child.count,
                               buckets=list(child.buckets),
                               counts=list(child.counts))
                else:
                    row["value"] = child.value
                rows.append(row)
            out[fam.name] = {"type": fam.kind, "help": fam.help,
                             "values": rows}
        return out

    def write_prom(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.exposition())

    def write_jsonl(self, path: str, extra: Optional[dict] = None) -> None:
        """Append one snapshot line — a time series accumulates across
        runs/ticks of the same file."""
        rec = {"ts": time.time(), **(extra or {}), "metrics": self.snapshot()}
        with open(path, "a") as f:
            f.write(json.dumps(rec) + "\n")


NULL_REGISTRY = MetricsRegistry(enabled=False)

_SAMPLE_RE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{(.*)\})?\s+(\S+)$')
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prom(text: str) -> Dict[Tuple[str, Tuple[Tuple[str, str], ...]], float]:
    """Parse Prometheus text exposition into {(name, sorted label items):
    value} — the round-trip half of `MetricsRegistry.exposition`, used by
    the tests and for offline snapshot diffing."""
    out: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"unparseable exposition line: {line!r}")
        name, _, labelblob, value = m.groups()
        labels = []
        for lm in _LABEL_RE.finditer(labelblob or ""):
            v = lm.group(2).replace('\\"', '"').replace("\\n", "\n") \
                           .replace("\\\\", "\\")
            labels.append((lm.group(1), v))
        v = float("inf") if value == "+Inf" else float(value)
        out[(name, tuple(sorted(labels)))] = v
    return out


def ticker_line(eng) -> str:
    """One-line live status for demo loops (examples/serve_continuous.py),
    sourced from the engine's registry: active slots / queue, prefix hit
    rate, speculative accept rate, and measured-vs-floor KV bytes."""
    m = eng.metrics
    hits = m.value("alloc_prefix_hit_pages_total")
    looked = hits + m.value("alloc_prefix_miss_pages_total")
    prop = m.value("serve_spec_proposed_total")
    acc = m.value("serve_spec_accepted_total")
    floor_b = m.value("serve_kv_floor_bytes_total")
    ach_b = m.value("serve_kv_achieved_bytes_total")
    return (f"tick {eng.tick:4d} | act {eng.active_count}/{eng.slots} "
            f"q{eng.sched.queue_depth}"
            f" | hit {hits / looked if looked else 0.0:4.0%}"
            f" | acc {acc / prop if prop else 0.0:4.0%}"
            f" | kv {eng.kv_bytes_per_token()} B/tok"
            f" | hbm {ach_b / floor_b if floor_b else 0.0:4.1f}x floor")
