"""Cache-mode configuration for the serving engine.

One `CacheConfig` selects the decode KV-cache representation end to end:

  * ``contiguous``  — PR-1 behaviour: one fixed [slots, capacity] bf16
    tensor per layer, worst-case capacity reserved per slot;
  * ``paged_bf16``  — fixed-size pages (default 16 tokens) drawn from a
    shared pool; per-request block tables; still bf16 values;
  * ``paged_ams``   — pages stored in the packed AMS-e2m2 layout from
    `repro.core.kv_quant` (hi-nibble plane + shared-LSB plane + per-
    (token, head) scales); each inserted K/V vector is quantized ONCE at
    insert and restored on the fly inside the attention loop.

The paged modes require every attention layer to be plain GQA (gqa /
gqa_moe patterns): sliding-window ring caches and MLA's compressed stream
keep their contiguous layouts for now (docs/paged_cache.md §Extensions).
"""

from __future__ import annotations

import dataclasses

PAGED_KINDS = ("paged_bf16", "paged_ams")
CACHE_KINDS = ("contiguous",) + PAGED_KINDS


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """How the engine stores and reads the decode KV cache."""

    kind: str = "contiguous"         # contiguous | paged_bf16 | paged_ams
    page_size: int = 16              # tokens per page
    num_pages: int = 0               # pool size (pages per layer); 0 = derive
    max_pages_per_seq: int = 0       # block-table width; 0 = derive
    kv_scheme: str = "fp4.25-e2m2"   # AMS scheme for paged_ams pages
    kv_strategy: str = "set_lsb"     # mantissa-sharing strategy at insert
    impl: str = "ref"                # ref | pallas | pallas_interpret
    prefix_cache: bool = True        # share completed prompt pages across
    #                                  requests (paged modes; see
    #                                  docs/paged_cache.md §Prefix caching)
    host_spill_pages: int = 0        # host-memory spill tier capacity, in
    #                                  pages (0 = tier off): evicted LRU
    #                                  pages and preempted requests' private
    #                                  pages spill here in packed form and
    #                                  restore bit-exactly (docs/
    #                                  paged_cache.md §Host spill tier)

    def __post_init__(self):
        kind = self.kind.replace("-", "_")
        object.__setattr__(self, "kind", kind)
        if kind not in CACHE_KINDS:
            raise ValueError(f"unknown cache kind {self.kind!r}; "
                             f"expected one of {CACHE_KINDS}")
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        if self.impl not in ("ref", "pallas", "pallas_interpret"):
            raise ValueError(f"unknown paged-attention impl {self.impl!r}")
        if self.host_spill_pages < 0:
            raise ValueError("host_spill_pages must be >= 0")

    @property
    def paged(self) -> bool:
        return self.kind in PAGED_KINDS

    @property
    def quantized(self) -> bool:
        return self.kind == "paged_ams"

    @property
    def content_key(self) -> str:
        """String committed into prefix-cache block hashes: two requests may
        share a physical page only when every byte of the page would be
        identical, which holds exactly when the storage scheme matches (the
        insert quantization is deterministic per (token, head))."""
        if self.quantized:
            return f"{self.kind}/{self.kv_scheme}/{self.kv_strategy}"
        return self.kind

    def sized(self, *, capacity: int, slots: int) -> "CacheConfig":
        """Fill derived sizes from the engine's (slots, capacity) request:
        block tables wide enough for `capacity` tokens, and a pool that can
        hold every slot at worst case unless `num_pages` was given."""
        mp = self.max_pages_per_seq or -(-capacity // self.page_size)
        np_ = self.num_pages or mp * slots
        return dataclasses.replace(self, max_pages_per_seq=mp, num_pages=np_)
