"""Host-side refcounted page allocator + content-addressed prefix cache.

The allocator owns the free/evictable state of the device page pool. It is
pure host state (plain ints and hashes), mirroring the scheduler's split:
device tensors never hold allocation metadata, so allocation/free/match is
O(pages) numpy work per request, not a jitted op.

Every page is in exactly ONE of three states:

    free (uncached)   --alloc-->   referenced (refcount >= 1)
    referenced        --free-->    free              (never published)
    referenced        --free-->    cached-evictable  (hash in the index)
    cached-evictable  --alloc-->   referenced        (prefix hit, ref += 1)
    cached-evictable  --evict-->   referenced        (reclaimed, hash dropped)

Prefix caching: completed PROMPT pages are content-addressed by a
prefix-chain block hash (`prefix_page_hashes`) committing to every token of
the page and its predecessors plus the cache scheme. Because the paged-AMS
pool quantizes each inserted K/V vector deterministically per (token, head)
(`core/kv_quant`), equal hashes imply bit-identical page planes — so a
later request with the same prompt prefix references the SAME physical page
(refcount += 1, read-only) and skips prefilling it entirely. Pages whose
refcount drains to zero keep their cached content in an LRU until memory
pressure reclaims them (least-recently-released first).

Pages are reserved for a request's WORST-CASE footprint at admission
(`ceil(kv_need / page_size)` pages), keeping the engine preemption-free,
but only the UNCACHED page count charges the free budget. `free` raises on
an unknown request id — a double free would otherwise silently corrupt the
free list.

Page index 0 is a valid data page like any other; block-table rows are
padded with 0 for unused entries. That is safe because attention masks
every key position >= the request's current length, so a padded entry is
never read as data — even when page 0 is simultaneously shared by other
requests.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.obs.metrics import NULL_REGISTRY


def prefix_page_hashes(tokens, page_size: int,
                       content_key: str = "") -> Tuple[bytes, ...]:
    """Prefix-chain hash per FULL page of `tokens`.

    Hash j commits to every token of pages 0..j, the page size, and
    `content_key` (the cache scheme — bf16 and AMS pages of the same tokens
    hold different bytes, and different AMS schemes different codes), so
    equal hashes imply bit-identical page content under the deterministic
    per-(token, head) insert quantization. A partial trailing page gets no
    hash: its remaining slots are filled by request-specific tokens.
    """
    toks = np.asarray(tokens, np.int64).reshape(-1)
    h = hashlib.sha256(f"{content_key}|{page_size}".encode()).digest()
    out = []
    for j in range(toks.shape[0] // page_size):
        page = toks[j * page_size:(j + 1) * page_size]
        h = hashlib.sha256(h + page.tobytes()).digest()
        out.append(h)
    return tuple(out)


class PageAllocator:
    """Refcounting allocator over `num_pages` fixed-size pages with a
    block-hash index of cached, evictable prefix pages (module docstring)."""

    def __init__(self, num_pages: int, page_size: int, metrics=None):
        if num_pages < 1:
            raise ValueError("num_pages must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        # telemetry (repro.obs): the engine passes its registry; a bare
        # allocator gets the shared no-op instruments. Occupancy is
        # exported as callback gauges so collection always sees live state.
        m = metrics if metrics is not None else NULL_REGISTRY
        self._m_alloc = m.counter("alloc_pages_total",
                                  "pages reserved, by kind", ("kind",))
        self._m_alloc_shared = self._m_alloc.labels(kind="shared")
        self._m_alloc_private = self._m_alloc.labels(kind="private")
        self._m_freed = m.counter("alloc_pages_freed_total",
                                  "page references released")
        self._m_evicted = m.counter("alloc_pages_evicted_total",
                                    "cached pages reclaimed under pressure")
        self._m_hit = m.counter("alloc_prefix_hit_pages_total",
                                "cacheable pages served from the index")
        self._m_miss = m.counter("alloc_prefix_miss_pages_total",
                                 "cacheable pages allocated private")
        m.gauge("alloc_pages_in_use", "pages referenced by live requests",
                fn=lambda: self.used_pages)
        m.gauge("alloc_pages_cached_evictable",
                "refcount-0 pages kept for prefix hits",
                fn=lambda: self.cached_pages)
        m.gauge("alloc_pages_free", "reclaimable supply (free + evictable)",
                fn=lambda: self.free_pages)
        # LIFO free list: freshly freed pages are reused first (their planes
        # are still warm in cache on real hardware)
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        # refcount-0 pages still holding published content, least recently
        # released first — the eviction order under memory pressure
        self._lru: "OrderedDict[int, bytes]" = OrderedDict()
        self._index: Dict[bytes, int] = {}   # block hash -> resident page
        self._hash: Dict[int, bytes] = {}    # page -> its published hash
        self._ref: Dict[int, int] = {}       # page -> refcount (>0 only)
        self._owned: Dict[int, List[int]] = {}   # rid -> pages
        # monotonic counters (reset via reset_stats)
        self.hits = 0         # cacheable pages served from the index at alloc
        self.misses = 0       # cacheable (hashed) pages allocated private —
        #                       generation-tail/partial pages can never hit,
        #                       so they don't dilute prefix_hit_rate
        self.evictions = 0    # cached pages reclaimed under pressure

    # ------------------------------------------------------------- queries
    @property
    def free_pages(self) -> int:
        """Reclaimable supply: truly-free pages plus evictable cached pages
        (the admission budget — cached pages are given up under pressure)."""
        return len(self._free) + len(self._lru)

    @property
    def used_pages(self) -> int:
        """Pages referenced by at least one in-flight request."""
        return self.num_pages - self.free_pages

    @property
    def cached_pages(self) -> int:
        """Evictable pages kept resident for future prefix hits."""
        return len(self._lru)

    def pages_needed(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_size)

    def match_prefix(self, hashes: Sequence[bytes]) -> int:
        """Longest resident prefix: how many leading `hashes` the index
        holds. Pure query — pins nothing."""
        n = 0
        for h in hashes:
            if h not in self._index:
                break
            n += 1
        return n

    def _admission(self, n_pages: int,
                   hashes: Sequence[bytes]) -> Tuple[int, bool]:
        """(matched prefix length, whether the request fits) — the single
        source of the budget arithmetic `can_alloc` and `alloc` share, so
        can_alloc() == True structurally guarantees alloc() succeeds. Only
        the UNCACHED page count charges the reclaimable supply; matched
        pages sitting in the LRU are pinned by the alloc, not spent."""
        matched = min(self.match_prefix(hashes), n_pages)
        pinned_from_lru = sum(1 for h in list(hashes)[:matched]
                              if self._index[h] in self._lru)
        return matched, n_pages - matched <= self.free_pages - pinned_from_lru

    def can_alloc(self, n_pages: int, hashes: Sequence[bytes] = ()) -> bool:
        """True iff `alloc(rid, n_pages, hashes)` would succeed."""
        return self._admission(n_pages, hashes)[1]

    # ------------------------------------------------------------ mutation
    def alloc(self, rid: int, n_pages: int,
              hashes: Sequence[bytes] = ()) -> Tuple[List[int], int]:
        """Reserve `n_pages` for request `rid`, shared-prefix pages first:
        the longest resident prefix of `hashes` is SHARED (refcount += 1,
        read-only for this request); the remainder is private, drawn from
        the free list or — under pressure — by evicting least-recently-used
        cached pages. Raises if the pool is short (callers gate on
        `can_alloc` — the scheduler's admission check). Returns
        ``(pages, n_shared)`` — the page list and the authoritative count
        of leading shared pages, which callers MUST use (not their own
        `match_prefix` rerun) to place their first insert position."""
        if rid in self._owned:
            raise ValueError(f"request {rid} already holds pages")
        matched, fits = self._admission(n_pages, hashes)
        if not fits:
            raise RuntimeError(
                f"page pool exhausted: need {n_pages}, free {self.free_pages}")
        pages: List[int] = []
        for h in list(hashes)[:matched]:        # pin the shared prefix
            p = self._index[h]
            if p in self._lru:
                del self._lru[p]
            self._ref[p] = self._ref.get(p, 0) + 1
            pages.append(p)
        for _ in range(n_pages - matched):      # private (insert-target)
            if self._free:
                p = self._free.pop()
            else:                               # reclaim coldest cached page
                p, h = self._lru.popitem(last=False)
                del self._index[h]
                del self._hash[p]
                self.evictions += 1
                self._m_evicted.inc()
            self._ref[p] = 1
            pages.append(p)
        self.hits += matched
        self.misses += min(len(hashes), n_pages) - matched
        self._m_hit.inc(matched)
        self._m_miss.inc(min(len(hashes), n_pages) - matched)
        self._m_alloc_shared.inc(matched)
        self._m_alloc_private.inc(n_pages - matched)
        self._owned[rid] = pages
        return pages, matched

    def publish(self, rid: int, h: bytes, page: int) -> bool:
        """Register a COMPLETED private page under its block hash so later
        requests can share it. No-op (False) when the hash is already
        resident — first writer wins; the duplicate page stays private and
        returns to the free list on release. Published pages stay
        bit-immutable because writers only ever insert past their cached
        prefix (asserted by the engine)."""
        if page not in self._owned.get(rid, ()):
            raise ValueError(f"request {rid} does not own page {page}")
        if h in self._index or page in self._hash:
            return False
        self._index[h] = page
        self._hash[page] = h
        return True

    def free(self, rid: int) -> int:
        """Release every page owned by `rid` (refcount -= 1); pages whose
        count drains to zero return to the free list, or to the evictable
        LRU tail when they hold published content. Returns how many pages
        the request held. Raises KeyError on an unknown rid: a double free
        would otherwise push pages onto the free list while other requests
        still reference them."""
        if rid not in self._owned:
            raise KeyError(
                f"free of unknown request {rid} (double free, or never "
                "allocated)")
        pages = self._owned.pop(rid)
        for p in pages:
            n = self._ref.get(p, 0)
            if n <= 0:
                raise RuntimeError(
                    f"page {p} released with refcount {n}: allocator state "
                    "corrupt")
            if n == 1:
                del self._ref[p]
                if p in self._hash:
                    self._lru[p] = self._hash[p]   # most recently released
                else:
                    self._free.append(p)
            else:
                self._ref[p] = n - 1
        self._m_freed.inc(len(pages))
        return len(pages)

    def block_table_row(self, rid: int, width: int) -> np.ndarray:
        """[width] int32 row for the device block table (0-padded)."""
        pages = self._owned.get(rid, [])
        if len(pages) > width:
            raise ValueError(
                f"request {rid} holds {len(pages)} pages > table width {width}")
        row = np.zeros(width, np.int32)
        row[: len(pages)] = pages
        return row

    # ---------------------------------------------------------- accounting
    def stats(self) -> Dict[str, float]:
        """Counter snapshot (`ServeEngine.stats()` re-exports these)."""
        looked = self.hits + self.misses
        return {
            "pages_total": self.num_pages,
            "pages_in_use": self.num_pages - self.free_pages,
            "pages_cached_evictable": len(self._lru),
            "pages_free_uncached": len(self._free),
            "prefix_hit_pages": self.hits,
            "prefix_miss_pages": self.misses,
            "prefix_hit_rate": self.hits / looked if looked else 0.0,
            "prefix_evictions": self.evictions,
        }

    def reset_stats(self) -> None:
        self.hits = self.misses = self.evictions = 0

    def check_invariants(self) -> None:
        """Structural invariants, used by the property tests: every page is
        in exactly one of {free, cached-evictable, referenced}; refcounts
        equal owner multiplicity; the hash index is a bijection onto
        resident published pages."""
        free, lru, ref = set(self._free), set(self._lru), set(self._ref)
        assert len(free) == len(self._free), "free list holds duplicates"
        assert not (free & lru) and not (free & ref) and not (lru & ref), \
            "page in two lifecycle states at once"
        assert (free | lru | ref) == set(range(self.num_pages)), \
            "pages leaked or invented"
        counts: Dict[int, int] = {}
        for pages in self._owned.values():
            for p in pages:
                counts[p] = counts.get(p, 0) + 1
        assert counts == self._ref, "refcounts != owner multiplicity"
        assert all(n > 0 for n in self._ref.values())
        assert self._index == {h: p for p, h in self._hash.items()}, \
            "hash index not a bijection"
        assert set(self._hash) <= (lru | ref), "published hash on free page"
