"""Host-side page allocator + per-request block tables.

The allocator owns the free list of the device page pool. It is pure host
state (plain ints), mirroring the scheduler's split: device tensors never
hold allocation metadata, so allocation/free is O(pages) numpy work per
request, not a jitted op.

Pages are reserved for a request's WORST-CASE footprint at admission
(`ceil(kv_need / page_size)` pages) and freed when the request completes —
admission-time reservation keeps the engine preemption-free, exactly like
the contiguous engine's submit-time capacity check, while many short
requests now reserve only their own pages instead of whole worst-case
slots.

Page index 0 is a valid data page like any other; block-table rows are
padded with 0 for unused entries. That is safe because attention masks
every key position >= the request's current length, so a padded entry is
never read as data.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np


class PageAllocator:
    """Free-list allocator over `num_pages` fixed-size pages."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 1:
            raise ValueError("num_pages must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list: freshly freed pages are reused first (their planes
        # are still warm in cache on real hardware)
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._owned: Dict[int, List[int]] = {}   # rid -> pages

    # ------------------------------------------------------------- queries
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def pages_needed(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_size)

    def can_alloc(self, n_pages: int) -> bool:
        return n_pages <= len(self._free)

    # ------------------------------------------------------------ mutation
    def alloc(self, rid: int, n_pages: int) -> List[int]:
        """Reserve `n_pages` for request `rid`. Raises if the pool is short
        (callers gate on `can_alloc` — the scheduler's admission check)."""
        if rid in self._owned:
            raise ValueError(f"request {rid} already holds pages")
        if not self.can_alloc(n_pages):
            raise RuntimeError(
                f"page pool exhausted: need {n_pages}, free {len(self._free)}")
        pages = [self._free.pop() for _ in range(n_pages)]
        self._owned[rid] = pages
        return pages

    def free(self, rid: int) -> int:
        """Release every page owned by `rid`; returns how many."""
        pages = self._owned.pop(rid, [])
        self._free.extend(pages)
        return len(pages)

    def block_table_row(self, rid: int, width: int) -> np.ndarray:
        """[width] int32 row for the device block table (0-padded)."""
        pages = self._owned.get(rid, [])
        if len(pages) > width:
            raise ValueError(
                f"request {rid} holds {len(pages)} pages > table width {width}")
        row = np.zeros(width, np.int32)
        row[: len(pages)] = pages
        return row
