"""Host-side refcounted page allocator + content-addressed prefix cache.

The allocator owns the free/evictable state of the device page pool. It is
pure host state (plain ints and hashes), mirroring the scheduler's split:
device tensors never hold allocation metadata, so allocation/free/match is
O(pages) numpy work per request, not a jitted op.

Every page is in exactly ONE of three states:

    free (uncached)   --alloc-->   referenced (refcount >= 1)
    referenced        --free-->    free              (never published)
    referenced        --free-->    cached-evictable  (hash in the index)
    cached-evictable  --alloc-->   referenced        (prefix hit, ref += 1)
    cached-evictable  --evict-->   referenced        (reclaimed, hash dropped)

Prefix caching: completed PROMPT pages are content-addressed by a
prefix-chain block hash (`prefix_page_hashes`) committing to every token of
the page and its predecessors plus the cache scheme. Because the paged-AMS
pool quantizes each inserted K/V vector deterministically per (token, head)
(`core/kv_quant`), equal hashes imply bit-identical page planes — so a
later request with the same prompt prefix references the SAME physical page
(refcount += 1, read-only) and skips prefilling it entirely. Pages whose
refcount drains to zero keep their cached content in an LRU until memory
pressure reclaims them (least-recently-released first).

Pages are reserved for a request's WORST-CASE footprint at admission
(`ceil(kv_need / page_size)` pages), but only the UNCACHED page count
charges the free budget. `free` raises on an unknown request id — a double
free would otherwise silently corrupt the free list.

Host spill tier (PR 10): one layer BELOW eviction. When memory pressure
reclaims a cached-evictable page and a host tier is configured
(`host_spill_pages` > 0 and the engine bound a `spill_fn`), the page's
packed planes move to a host-memory LRU keyed by the same block hash
instead of being dropped. Prefix matching then extends over host-resident
hashes: a later request with that prefix draws a FRESH device page, the
(page, host content) pair is queued on `pending_restores` for the engine to
scatter back before its first step, and the page re-enters the index — so
a host hit still skips prefill, at the cost of one host->device copy
instead of recompute. Preemption (`preempt`/`resume`) releases a victim's
pages past its shared prefix while the engine snapshots their content onto
the request itself; `resume` re-extends with fresh pages for the engine to
restore. AMS planes travel packed in both directions, so every round trip
is bit-exact.

Page index 0 is a valid data page like any other; block-table rows are
padded with 0 for unused entries. That is safe because attention masks
every key position >= the request's current length, so a padded entry is
never read as data — even when page 0 is simultaneously shared by other
requests.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.obs.metrics import NULL_REGISTRY


def prefix_page_hashes(tokens, page_size: int,
                       content_key: str = "") -> Tuple[bytes, ...]:
    """Prefix-chain hash per FULL page of `tokens`.

    Hash j commits to every token of pages 0..j, the page size, and
    `content_key` (the cache scheme — bf16 and AMS pages of the same tokens
    hold different bytes, and different AMS schemes different codes), so
    equal hashes imply bit-identical page content under the deterministic
    per-(token, head) insert quantization. A partial trailing page gets no
    hash: its remaining slots are filled by request-specific tokens.
    """
    toks = np.asarray(tokens, np.int64).reshape(-1)
    h = hashlib.sha256(f"{content_key}|{page_size}".encode()).digest()
    out = []
    for j in range(toks.shape[0] // page_size):
        page = toks[j * page_size:(j + 1) * page_size]
        h = hashlib.sha256(h + page.tobytes()).digest()
        out.append(h)
    return tuple(out)


class PageAllocator:
    """Refcounting allocator over `num_pages` fixed-size pages with a
    block-hash index of cached, evictable prefix pages (module docstring)."""

    def __init__(self, num_pages: int, page_size: int, metrics=None,
                 host_spill_pages: int = 0):
        if num_pages < 1:
            raise ValueError("num_pages must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        # host spill tier: block hash -> host-side page pytree (packed
        # planes), least recently spilled first. Active only when sized AND
        # the engine bound `spill_fn(page) -> host pytree` (the allocator
        # itself never touches device memory).
        self.host_spill_pages = host_spill_pages
        self.spill_fn = None
        self._host: "OrderedDict[bytes, object]" = OrderedDict()
        # (device page, host content) pairs the engine must scatter back
        # into the pool before the owning request's next step
        self.pending_restores: List[Tuple[int, object]] = []
        # telemetry (repro.obs): the engine passes its registry; a bare
        # allocator gets the shared no-op instruments. Occupancy is
        # exported as callback gauges so collection always sees live state.
        m = metrics if metrics is not None else NULL_REGISTRY
        self._m_alloc = m.counter("alloc_pages_total",
                                  "pages reserved, by kind", ("kind",))
        self._m_alloc_shared = self._m_alloc.labels(kind="shared")
        self._m_alloc_private = self._m_alloc.labels(kind="private")
        self._m_freed = m.counter("alloc_pages_freed_total",
                                  "page references released")
        self._m_evicted = m.counter("alloc_pages_evicted_total",
                                    "cached pages reclaimed under pressure")
        self._m_hit = m.counter("alloc_prefix_hit_pages_total",
                                "cacheable pages served from the index")
        self._m_miss = m.counter("alloc_prefix_miss_pages_total",
                                 "cacheable pages allocated private")
        m.gauge("alloc_pages_in_use", "pages referenced by live requests",
                fn=lambda: self.used_pages)
        m.gauge("alloc_pages_cached_evictable",
                "refcount-0 pages kept for prefix hits",
                fn=lambda: self.cached_pages)
        m.gauge("alloc_pages_free", "reclaimable supply (free + evictable)",
                fn=lambda: self.free_pages)
        self._m_spilled = m.counter(
            "alloc_pages_spilled_host_total",
            "evicted pages offloaded to the host spill tier")
        self._m_restored = m.counter(
            "alloc_pages_restored_host_total",
            "host-tier pages restored into fresh device pages")
        m.gauge("alloc_pages_host_tier",
                "pages resident in the host spill tier",
                fn=lambda: len(self._host))
        # LIFO free list: freshly freed pages are reused first (their planes
        # are still warm in cache on real hardware)
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        # refcount-0 pages still holding published content, least recently
        # released first — the eviction order under memory pressure
        self._lru: "OrderedDict[int, bytes]" = OrderedDict()
        self._index: Dict[bytes, int] = {}   # block hash -> resident page
        self._hash: Dict[int, bytes] = {}    # page -> its published hash
        self._ref: Dict[int, int] = {}       # page -> refcount (>0 only)
        self._owned: Dict[int, List[int]] = {}   # rid -> pages
        # monotonic counters (reset via reset_stats)
        self.hits = 0         # cacheable pages served from the index at alloc
        self.misses = 0       # cacheable (hashed) pages allocated private —
        #                       generation-tail/partial pages can never hit,
        #                       so they don't dilute prefix_hit_rate
        self.evictions = 0    # cached pages reclaimed under pressure
        self.host_spills = 0     # evicted pages whose content moved to host
        self.host_restores = 0   # host-tier pages brought back on a hit

    # ------------------------------------------------------------- queries
    @property
    def free_pages(self) -> int:
        """Reclaimable supply: truly-free pages plus evictable cached pages
        (the admission budget — cached pages are given up under pressure)."""
        return len(self._free) + len(self._lru)

    @property
    def used_pages(self) -> int:
        """Pages referenced by at least one in-flight request."""
        return self.num_pages - self.free_pages

    @property
    def cached_pages(self) -> int:
        """Evictable pages kept resident for future prefix hits."""
        return len(self._lru)

    def pages_needed(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_size)

    def refcount(self, page: int) -> int:
        """Live references to `page` (0 = free or cached-evictable)."""
        return self._ref.get(page, 0)

    def match_prefix(self, hashes: Sequence[bytes]) -> int:
        """Longest resident prefix: how many leading `hashes` the index
        holds. Pure query — pins nothing."""
        n = 0
        for h in hashes:
            if h not in self._index:
                break
            n += 1
        return n

    def _classify_prefix(self, hashes: Sequence[bytes],
                         n_pages: int) -> List[str]:
        """Leading run of `hashes` servable WITHOUT prefill: each entry is
        ``"resident"`` (a shared physical page) or ``"host"`` (content in
        the spill tier — needs a fresh page plus a queued restore); the run
        stops at the first hash in neither tier."""
        kinds: List[str] = []
        for h in list(hashes)[:n_pages]:
            if h in self._index:
                kinds.append("resident")
            elif h in self._host:
                kinds.append("host")
            else:
                break
        return kinds

    def _admission(self, n_pages: int,
                   hashes: Sequence[bytes]) -> Tuple[List[str], bool]:
        """(prefix classification, whether the request fits) — the single
        source of the budget arithmetic `can_alloc` and `alloc` share, so
        can_alloc() == True structurally guarantees alloc() succeeds. Only
        pages drawn fresh (privates + host-tier restores) charge the
        reclaimable supply; resident matched pages sitting in the LRU are
        pinned by the alloc, not spent."""
        kinds = self._classify_prefix(hashes, n_pages)
        hl = list(hashes)
        resident = sum(1 for k in kinds if k == "resident")
        pinned_from_lru = sum(1 for i, k in enumerate(kinds)
                              if k == "resident" and self._index[hl[i]] in self._lru)
        return kinds, n_pages - resident <= self.free_pages - pinned_from_lru

    def can_alloc(self, n_pages: int, hashes: Sequence[bytes] = ()) -> bool:
        """True iff `alloc(rid, n_pages, hashes)` would succeed."""
        return self._admission(n_pages, hashes)[1]

    # ------------------------------------------------------------ mutation
    def _reclaim_coldest(self) -> int:
        """Evict the least-recently-released cached page, spilling its
        content to the host tier first when one is configured (the tier's
        own LRU drops ITS oldest entry past capacity — that is the true end
        of the page lifecycle: device -> host -> gone)."""
        p, h = self._lru.popitem(last=False)
        if self.host_spill_pages > 0 and self.spill_fn is not None:
            self._host[h] = self.spill_fn(p)
            self._host.move_to_end(h)
            self.host_spills += 1
            self._m_spilled.inc()
            while len(self._host) > self.host_spill_pages:
                self._host.popitem(last=False)
        del self._index[h]
        del self._hash[p]
        self.evictions += 1
        self._m_evicted.inc()
        return p

    def alloc(self, rid: int, n_pages: int,
              hashes: Sequence[bytes] = ()) -> Tuple[List[int], int]:
        """Reserve `n_pages` for request `rid`, shared-prefix pages first:
        the longest resident prefix of `hashes` is SHARED (refcount += 1,
        read-only for this request); the remainder is private, drawn from
        the free list or — under pressure — by evicting least-recently-used
        cached pages. Raises if the pool is short (callers gate on
        `can_alloc` — the scheduler's admission check). Returns
        ``(pages, n_shared)`` — the page list and the authoritative count
        of leading shared pages, which callers MUST use (not their own
        `match_prefix` rerun) to place their first insert position."""
        if rid in self._owned:
            raise ValueError(f"request {rid} already holds pages")
        kinds, fits = self._admission(n_pages, hashes)
        if not fits:
            raise RuntimeError(
                f"page pool exhausted: need {n_pages}, free {self.free_pages}")
        matched = len(kinds)
        hl = list(hashes)
        pages: List[int] = [-1] * n_pages
        # pass 1: pin every RESIDENT shared page, and claim every matched
        # host-tier content blob, BEFORE drawing any fresh page — drawing
        # evicts LRU pages (which could be a later resident match) and can
        # overflow the host tier (which could drop a later host match)
        restores: Dict[int, object] = {}
        for i, k in enumerate(kinds):
            if k == "resident":
                p = self._index[hl[i]]
                if p in self._lru:
                    del self._lru[p]
                self._ref[p] = self._ref.get(p, 0) + 1
                pages[i] = p
            else:                               # host-tier hit
                restores[i] = self._host.pop(hl[i])
        # pass 2: fresh pages for host-tier hits (restore queued, hash
        # re-registered as resident) and for plain privates (insert-target)
        for i in range(n_pages):
            if pages[i] >= 0:
                continue
            if self._free:
                p = self._free.pop()
            else:                               # reclaim coldest cached page
                p = self._reclaim_coldest()
            self._ref[p] = 1
            pages[i] = p
            if i in restores:
                self.pending_restores.append((p, restores[i]))
                self._index[hl[i]] = p
                self._hash[p] = hl[i]
                self.host_restores += 1
                self._m_restored.inc()
        n_resident = matched - len(restores)
        self.hits += matched
        self.misses += min(len(hashes), n_pages) - matched
        self._m_hit.inc(matched)
        self._m_miss.inc(min(len(hashes), n_pages) - matched)
        self._m_alloc_shared.inc(n_resident)
        self._m_alloc_private.inc(n_pages - n_resident)
        self._owned[rid] = pages
        return pages, matched

    def publish(self, rid: int, h: bytes, page: int) -> bool:
        """Register a COMPLETED private page under its block hash so later
        requests can share it. No-op (False) when the hash is already
        resident — first writer wins; the duplicate page stays private and
        returns to the free list on release. Published pages stay
        bit-immutable because writers only ever insert past their cached
        prefix (asserted by the engine)."""
        if page not in self._owned.get(rid, ()):
            raise ValueError(f"request {rid} does not own page {page}")
        if h in self._index or page in self._hash:
            return False
        # a re-prefilled copy supersedes any host-tier spill of the same
        # content (equal hashes imply identical bytes) — drop the host copy
        # so each hash lives in exactly one tier
        self._host.pop(h, None)
        self._index[h] = page
        self._hash[page] = h
        return True

    def free(self, rid: int) -> int:
        """Release every page owned by `rid` (refcount -= 1); pages whose
        count drains to zero return to the free list, or to the evictable
        LRU tail when they hold published content. Returns how many pages
        the request held. Raises KeyError on an unknown rid: a double free
        would otherwise push pages onto the free list while other requests
        still reference them."""
        if rid not in self._owned:
            raise KeyError(
                f"free of unknown request {rid} (double free, or never "
                "allocated)")
        pages = self._owned.pop(rid)
        for p in pages:
            self._release_page(p)
        self._m_freed.inc(len(pages))
        return len(pages)

    def _release_page(self, p: int) -> None:
        """Drop one reference: refcount-0 pages return to the free list, or
        to the evictable LRU tail when they hold published content."""
        n = self._ref.get(p, 0)
        if n <= 0:
            raise RuntimeError(
                f"page {p} released with refcount {n}: allocator state "
                "corrupt")
        if n == 1:
            del self._ref[p]
            if p in self._hash:
                self._lru[p] = self._hash[p]   # most recently released
            else:
                self._free.append(p)
        else:
            self._ref[p] = n - 1

    # ---------------------------------------------------------- preemption
    def preempt(self, rid: int, n_keep: int) -> List[int]:
        """Release every page `rid` holds PAST its first `n_keep` (the
        shared prefix stays pinned, keeping its refcounts — the ISSUE's
        'spilled pages keep refcounts' contract): released refcounts drop
        exactly like `free`, so published pages move to the evictable LRU
        and unpublished privates to the free list. The rid keeps its
        (possibly empty) kept-page list so `resume` can extend it. Returns
        the released page ids in position order; the ENGINE must snapshot
        their content (`pool.extract_pages`) BEFORE calling this, because a
        released page may be reused by the very next alloc."""
        if rid not in self._owned:
            raise KeyError(f"preempt of unknown request {rid}")
        pages = self._owned[rid]
        n_keep = max(0, min(n_keep, len(pages)))
        released = pages[n_keep:]
        self._owned[rid] = pages[:n_keep]
        for p in released:
            self._release_page(p)
        self._m_freed.inc(len(released))
        return released

    def can_resume(self, rid: int, n_pages: int) -> bool:
        """True iff `resume(rid, n_pages)` would succeed (kept pages are
        already pinned, so only the extension charges the supply)."""
        held = len(self._owned.get(rid, ()))
        return n_pages - held <= self.free_pages

    def resume(self, rid: int, n_pages: int) -> List[int]:
        """Extend a preempted request back to `n_pages` total with fresh
        private pages appended after its kept shared prefix. Returns the
        NEW page ids in position order; the engine scatters the request's
        spilled content into them before its next step, after which the
        request is bit-indistinguishable from one that was never
        preempted."""
        if rid not in self._owned:
            raise KeyError(f"resume of unknown request {rid}")
        held = self._owned[rid]
        need = n_pages - len(held)
        if need > self.free_pages:
            raise RuntimeError(
                f"page pool exhausted on resume: need {need}, "
                f"free {self.free_pages}")
        new: List[int] = []
        for _ in range(max(need, 0)):
            p = self._free.pop() if self._free else self._reclaim_coldest()
            self._ref[p] = 1
            new.append(p)
        held.extend(new)
        self._m_alloc_private.inc(len(new))
        return new

    def block_table_row(self, rid: int, width: int) -> np.ndarray:
        """[width] int32 row for the device block table (0-padded)."""
        pages = self._owned.get(rid, [])
        if len(pages) > width:
            raise ValueError(
                f"request {rid} holds {len(pages)} pages > table width {width}")
        row = np.zeros(width, np.int32)
        row[: len(pages)] = pages
        return row

    # ---------------------------------------------------------- accounting
    def stats(self) -> Dict[str, float]:
        """Counter snapshot (`ServeEngine.stats()` re-exports these)."""
        looked = self.hits + self.misses
        return {
            "pages_total": self.num_pages,
            "pages_in_use": self.num_pages - self.free_pages,
            "pages_cached_evictable": len(self._lru),
            "pages_free_uncached": len(self._free),
            "prefix_hit_pages": self.hits,
            "prefix_miss_pages": self.misses,
            "prefix_hit_rate": self.hits / looked if looked else 0.0,
            "prefix_evictions": self.evictions,
            "pages_host_tier": len(self._host),
            "host_spill_pages_total": self.host_spills,
            "host_restore_pages_total": self.host_restores,
        }

    def reset_stats(self) -> None:
        self.hits = self.misses = self.evictions = 0
        self.host_spills = self.host_restores = 0

    def check_invariants(self) -> None:
        """Structural invariants, used by the property tests: every page is
        in exactly one of {free, cached-evictable, referenced}; refcounts
        equal owner multiplicity; the hash index is a bijection onto
        resident published pages."""
        free, lru, ref = set(self._free), set(self._lru), set(self._ref)
        assert len(free) == len(self._free), "free list holds duplicates"
        assert not (free & lru) and not (free & ref) and not (lru & ref), \
            "page in two lifecycle states at once"
        assert (free | lru | ref) == set(range(self.num_pages)), \
            "pages leaked or invented"
        counts: Dict[int, int] = {}
        for pages in self._owned.values():
            for p in pages:
                counts[p] = counts.get(p, 0) + 1
        assert counts == self._ref, "refcounts != owner multiplicity"
        assert all(n > 0 for n in self._ref.values())
        assert self._index == {h: p for p, h in self._hash.items()}, \
            "hash index not a bijection"
        assert set(self._hash) <= (lru | ref), "published hash on free page"
        assert not (set(self._host) & set(self._index)), \
            "hash resident on device AND in the host tier"
        assert len(self._host) <= max(self.host_spill_pages, 0), \
            "host spill tier over capacity"
