"""Paged AMS-quantized KV-cache subsystem.

Layers (host -> device -> kernel):

  * `config.CacheConfig`      — cache-mode selection + derived sizes
  * `allocator.PageAllocator` — host-side refcounting free list, block-hash
                                prefix index (shared pages), block-table
                                rows
  * `pool`                    — device page pools (bf16 or AMS packed
                                planes), single-scatter insert, page gather
  * `ref`                     — lattice-exact dequantize-then-attend oracle
  * `paged_attention`         — Pallas kernel walking the block table and
                                restoring AMS pages inside the attention loop

`paged_attend(...)` below dispatches on `CacheConfig.impl`; the model
layer (`repro.models.attention.gqa_attn_decode_paged`) is the only caller.
See docs/paged_cache.md for the page layout and bits/value accounting.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from .allocator import PageAllocator, prefix_page_hashes  # noqa: F401
from .config import CACHE_KINDS, PAGED_KINDS, CacheConfig  # noqa: F401
from .pool import (  # noqa: F401
    compression_vs_bf16,
    extract_pages,
    gather_kv,
    gather_pages,
    host_bytes,
    make_gqa_page_pool,
    paged_insert,
    paged_truncate,
    pool_bytes_per_token,
    restore_pages,
)
from .ref import paged_attention_ref  # noqa: F401


def paged_attend(q: jnp.ndarray, pool, lengths: jnp.ndarray,
                 block_table: jnp.ndarray, ccfg: CacheConfig, *,
                 kv_map: np.ndarray, scale: Optional[float] = None) -> jnp.ndarray:
    """impl-dispatching paged flash-decode: q [B, H, hd] -> [B, H, hd], or a
    ragged chunk q [B, c, H, hd] with per-query ``lengths`` [B, c] ->
    [B, c, H, hd] (multi-query-per-request, the chunked-prefill step)."""
    if ccfg.impl == "ref":
        return paged_attention_ref(q, pool, lengths, block_table, ccfg,
                                   kv_map=kv_map, scale=scale)
    from .paged_attention import paged_attention_pallas
    # the kernel assumes the group-major head layout; every model-zoo config
    # emits exactly that (kv_index_map), asserted here against kv_map
    H = q.shape[-2]
    kv_n = int(np.max(kv_map)) + 1 if len(kv_map) else 1
    if H % kv_n != 0 or not np.array_equal(kv_map, np.arange(H) // (H // kv_n)):
        raise NotImplementedError(
            "pallas paged attention requires the group-major GQA layout")
    return paged_attention_pallas(q, pool, lengths, block_table, ccfg,
                                  scale=scale,
                                  interpret=(ccfg.impl == "pallas_interpret"))
