"""Device-side page pools: storage layout, insert, and page gather.

One attention layer's decode cache is a POOL of fixed-size pages instead of
a [slots, capacity] tensor:

    bf16 pool : k/v each [P, page, kv, hd]            (P = num_pages)
    AMS pool  : k/v each {hi   [P, page, kv, hd_p/2]  int8   (2 codes/byte)
                          lsb  [P, page, kv, gw]      int32  (1 bit/k-group)
                          scale[P, page, kv, 1]       f32}

i.e. the AMS layout is exactly `repro.core.kv_quant`'s packed planes with a
(page, slot-in-page, head) prefix. A request's logical position i lives at
``page = block_table[slot, i // page_size], offset = i % page_size``; the
same block-table row addresses every layer's pool (each layer has its own
pool of the same geometry, vLLM-style).

Inserts are one scatter per plane per layer and take a [B, c] token BLOCK
(c = 1 is the single-token decode case; the ragged engine step packs up to
C prefill tokens per slot per tick): suppressed writes (idle slot pos < 0,
or chunk entries past a slot's valid count) are routed to an out-of-range
page index and dropped by the scatter — no full-pool select ever
materializes. Each token is quantized ONCE at insert; history is never
repacked.

This module is model-free (no `repro.models` import) so the model layer can
build on it without an import cycle.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import AMSFormat, get_scheme
from repro.core.kv_quant import (
    dequantize_kv,
    kv_bytes,
    packed_head_dim,
    quantize_kv,
)

from .config import CacheConfig


# ---------------------------------------------------------------- creation
def make_gqa_page_pool(ccfg: CacheConfig, kv: int, hd: int,
                       dtype=jnp.bfloat16) -> Dict:
    """Zero-initialized k/v page pools for one GQA layer."""
    P, page = ccfg.num_pages, ccfg.page_size
    if ccfg.quantized:
        scheme = get_scheme(ccfg.kv_scheme)
        hd_p = packed_head_dim(hd, scheme)
        gw = -(-(hd_p // scheme.k) // 32)

        def planes():
            return {"hi": jnp.zeros((P, page, kv, hd_p // 2), jnp.int8),
                    "lsb": jnp.zeros((P, page, kv, gw), jnp.int32),
                    "scale": jnp.zeros((P, page, kv, 1), jnp.float32)}

        return {"k": planes(), "v": planes()}
    return {"k": jnp.zeros((P, page, kv, hd), dtype),
            "v": jnp.zeros((P, page, kv, hd), dtype)}


# ------------------------------------------------------------------ insert
def _page_offset(pos, nvalid, block_table, ccfg: CacheConfig,
                 num_pages: int, c: int):
    """Physical (page, offset) [B, c] for a chunk starting at ``pos`` per
    slot; suppressed writes (idle slot, or chunk index >= nvalid) -> page
    index P (out of range, dropped by the scatter's mode='drop')."""
    j = jnp.arange(c, dtype=jnp.int32)[None, :]
    p = pos[:, None] + j                                      # [B, c]
    ok = (pos[:, None] >= 0) & (j < nvalid[:, None])
    logical = jnp.clip(p // ccfg.page_size, 0, block_table.shape[1] - 1)
    page = jnp.take_along_axis(block_table, logical, axis=1)  # [B, c]
    page = jnp.where(ok, page, num_pages)
    off = jnp.clip(p % ccfg.page_size, 0, ccfg.page_size - 1)
    return page, off


def paged_insert(pool: Dict, k_new: jnp.ndarray, v_new: jnp.ndarray,
                 pos: jnp.ndarray, block_table: jnp.ndarray,
                 ccfg: CacheConfig, nvalid=None) -> Dict:
    """Write this tick's K/V block ([B, c, kv, hd], c >= 1) into the layer
    pool — one scatter per plane packs all c tokens per slot.

    ``pos`` is [B] int32 per-slot START positions (negative = idle slot,
    write dropped); ``nvalid`` [B] int32 bounds each slot's valid chunk
    entries (default: every entry of non-idle slots — the single-token
    contract when c == 1); ``block_table`` is [B, max_pages_per_seq] int32.
    AMS pools quantize each written vector ONCE here, history untouched.

    Block-table rows may mix SHARED (prefix-cached, read-only) and private
    pages: the insert never distinguishes them — it writes wherever
    ``pos`` points — so callers must keep ``pos`` past the shared prefix
    (the engine starts each slot at its cached length and asserts it).
    """
    c = k_new.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    if nvalid is None:
        nvalid = jnp.where(pos >= 0, c, 0)
    num_pages = jax.tree.leaves(pool["k"])[0].shape[0]
    page, off = _page_offset(pos, jnp.asarray(nvalid, jnp.int32),
                             block_table, ccfg, num_pages, c)

    def write(leaf, val):
        return leaf.at[page, off].set(val.astype(leaf.dtype), mode="drop")

    if ccfg.quantized:
        scheme = get_scheme(ccfg.kv_scheme)
        out = {}
        for name, new in (("k", k_new), ("v", v_new)):
            q = quantize_kv(new, scheme, ccfg.kv_strategy)  # [B, c, kv, *]
            out[name] = {pl: write(pool[name][pl], q[pl])
                         for pl in ("hi", "lsb", "scale")}
        return out
    return {"k": write(pool["k"], k_new),
            "v": write(pool["v"], v_new)}


# ---------------------------------------------------------------- truncate
def paged_truncate(pool: Dict, start: jnp.ndarray, count: jnp.ndarray,
                   block_table: jnp.ndarray, ccfg: CacheConfig,
                   c_max: int) -> Dict:
    """Un-insert ``count`` positions starting at ``start`` per slot: the
    addressed (page, offset) entries of every plane are zero-scattered back
    to the pool's INITIAL state, so a later re-insert at those positions is
    bit-indistinguishable from a straight insert (insert quantization is
    deterministic). The speculative engine step calls this in-program to
    roll back rejected draft tokens; slots with ``count == 0`` (or idle
    ``start < 0``) are no-ops via the same out-of-range-page drop the
    insert path uses. ``c_max`` is the static rewind width bound (the
    step's speculate_k)."""
    num_pages = jax.tree.leaves(pool["k"])[0].shape[0]
    page, off = _page_offset(jnp.asarray(start, jnp.int32),
                             jnp.asarray(count, jnp.int32),
                             block_table, ccfg, num_pages, c_max)
    return jax.tree.map(
        lambda leaf: leaf.at[page, off].set(
            jnp.zeros((), leaf.dtype), mode="drop"), pool)


# ------------------------------------------------------------------ gather
def gather_pages(leaf: jnp.ndarray, block_table: jnp.ndarray) -> jnp.ndarray:
    """[P, page, ...] pool leaf -> [B, max_pages*page, ...] per-slot view."""
    B, mp = block_table.shape
    g = jnp.take(leaf, block_table.reshape(-1), axis=0)
    return g.reshape(B, mp * leaf.shape[1], *leaf.shape[2:])


def gather_kv(pool: Dict, block_table: jnp.ndarray, hd: int,
              ccfg: CacheConfig, dtype=jnp.bfloat16):
    """Materialize (k, v) [B, S_max, kv, hd] views of a layer pool, restoring
    AMS planes to their exact lattice values when the pool is quantized."""
    if ccfg.quantized:
        scheme = get_scheme(ccfg.kv_scheme)
        k_pl, v_pl = ({pl: gather_pages(pool[n][pl], block_table)
                       for pl in ("hi", "lsb", "scale")} for n in ("k", "v"))
        return (dequantize_kv(k_pl, hd, scheme, dtype),
                dequantize_kv(v_pl, hd, scheme, dtype))
    return (gather_pages(pool["k"], block_table).astype(dtype),
            gather_pages(pool["v"], block_table).astype(dtype))


# -------------------------------------------------------------- host spill
# Every pool plane is [..., P, page, kv, last] — exactly 4 trailing dims
# (bf16 k/v, or the AMS hi/lsb/scale planes), with one optional leading
# layer-group dim from `models.make_cache`. The page axis is therefore
# always ``ndim - 4``, which lets the spill helpers address pages across
# the WHOLE cache pytree without knowing the model's layer grouping.

def _page_index(leaf, ids):
    return (slice(None),) * (leaf.ndim - 4) + (ids,)


def extract_pages(cache, page_ids):
    """Copy the addressed pool pages of every plane to HOST memory, in the
    pool's storage layout — AMS pages stay PACKED (hi/lsb/scale planes),
    never dequantized, so a later `restore_pages` is bit-exact by
    construction. Returns a numpy pytree mirroring ``cache`` with the page
    axis narrowed to ``len(page_ids)``. This is the preemption/eviction
    spill path: one device->host transfer per plane, sized to the spilled
    pages only (never the whole pool)."""
    ids = jnp.asarray(page_ids, jnp.int32)
    return jax.tree.map(
        lambda leaf: np.asarray(jnp.take(leaf, ids, axis=leaf.ndim - 4)),
        cache)


def restore_pages(cache, page_ids, host):
    """Write a `extract_pages` snapshot back into the pool at (possibly
    different) ``page_ids``: one scatter per plane, byte-identical content.
    The restored pages are bit-indistinguishable from the originals — for
    AMS pools the packed planes round-trip exactly, so a resumed request's
    attention reads the same lattice values it would have read
    uninterrupted."""
    ids = jnp.asarray(page_ids, jnp.int32)
    return jax.tree.map(
        lambda leaf, val: leaf.at[_page_index(leaf, ids)].set(
            jnp.asarray(val, leaf.dtype)),
        cache, host)


def host_bytes(host) -> int:
    """Host-tier bytes a spilled-page pytree occupies (accounting)."""
    return sum(leaf.nbytes for leaf in jax.tree.leaves(host))


# -------------------------------------------------------------- accounting
def pool_bytes_per_token(kv: int, hd: int, ccfg: CacheConfig) -> int:
    """Cache bytes one token occupies in one layer (k + v)."""
    if ccfg.quantized:
        packed, _ = kv_bytes(hd, get_scheme(ccfg.kv_scheme))
        return 2 * kv * packed
    return 2 * kv * hd * 2


def compression_vs_bf16(kv: int, hd: int, ccfg: CacheConfig) -> float:
    """bf16 bytes / this cache-mode bytes, per token per layer."""
    bf16 = 2 * kv * hd * 2
    return bf16 / pool_bytes_per_token(kv, hd, ccfg)
