"""Lattice-exact reference for paged attention: gather -> dequantize -> attend.

The oracle every lowering of `kernels.attention_template` is tested
against (fused paged bf16/AMS, fused contiguous, and the verbatim XLA ref
bodies the template re-exports), and the production XLA fallback when
Pallas is unavailable on the target. Pages are gathered into
a per-slot [B, max_pages*page, kv, hd] view via the block table, AMS planes
are restored to their EXACT lattice values (`dequantize_kv` is bit-faithful
to the packed codes), and the existing `flash_decode` online-softmax core
attends with per-slot lengths.

Two exactness properties tests pin:

  * paged-bf16 with ``max_pages*page == capacity`` is BIT-IDENTICAL to the
    contiguous-slot decode path — the gathered view has the same shape and
    the same values at every valid position, and masked positions contribute
    exact zeros either way;
  * paged-AMS dequantizes to the same lattice points as a direct
    ``quantize_kv``/``dequantize_kv`` round trip — attention then differs
    from the Pallas kernel only by f32 reduction order.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from .config import CacheConfig
from .pool import gather_kv


def paged_attention_ref(
    q: jnp.ndarray,              # [B, H, hd] or [B, c, H, hd] (UNSCALED)
    pool,                        # layer pool (see cache.pool)
    lengths: jnp.ndarray,        # [B] int32 valid keys per slot (<=0: idle);
                                 #   [B, c] per-QUERY lengths when q is a
                                 #   ragged chunk (multi-query-per-request)
    block_table: jnp.ndarray,    # [B, max_pages_per_seq] int32
    ccfg: CacheConfig,
    *,
    kv_map: np.ndarray,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    # function-level import: models.attention layers on top of repro.cache
    from repro.models.attention import flash_decode, flash_decode_chunk

    hd = q.shape[-1]
    dtype = jnp.float32 if ccfg.quantized else q.dtype
    k, v = gather_kv(pool, block_table, hd, ccfg, dtype=dtype)
    if q.ndim == 4:   # chunked: intra-chunk causality rides in lengths
        return flash_decode_chunk(q, k, v, lengths, kv_map=kv_map,
                                  scale=scale)
    return flash_decode(q, k, v, lengths, kv_map=kv_map, scale=scale)
