"""Paged-attention decode over AMS-packed (or bf16) pages — template shim.

The kernel that used to live here (grid (slot, kv-head, page), block table
+ ragged per-query lengths on scalar prefetch, in-VREG e2m2 restoration,
online-softmax scratch across the page dim) is now ONE INSTANTIATION of
the fused attention template — see `repro.kernels.attention_template`,
which the contiguous GQA/MLA decode cores lower through as well. This
module keeps the `CacheConfig`-facing entry point (`cache/__init__.py`
dispatches here for impl "pallas"/"pallas_interpret") and re-exports the
in-kernel helpers for their historical import path.

Behavioral contract is unchanged and pinned by tests/test_paged_cache.py:
lattice-exact vs the `cache.ref` gather-dequantize oracle up to f32
reduction order, exact zeros for idle slots.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

# re-exports: these helpers lived here before the template unification
from repro.kernels.attention_template import (  # noqa: F401
    NEG_BIG,
    NEG_CLAMP,
    fused_paged_attention,
    online_softmax_step,
    restore_page,
    row_lengths,
)

from .config import CacheConfig


def paged_attention_pallas(
    q: jnp.ndarray,              # [B, H, hd] or [B, c, H, hd] UNSCALED
    pool,                        # layer pool (cache.pool layout)
    lengths: jnp.ndarray,        # [B] int32 valid keys (<=0: idle slot);
                                 #   [B, c] per-query for chunked q
    block_table: jnp.ndarray,    # [B, max_pages_per_seq] int32
    ccfg: CacheConfig,
    *,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Paged flash-decode via the fused template: unpack the CacheConfig
    into the template's plain parameters (page size, AMS scheme) and
    launch. Requires the group-major GQA head layout; returns q's shape in
    q.dtype."""
    return fused_paged_attention(
        q, pool, lengths, block_table,
        page_size=ccfg.page_size,
        kv_scheme=ccfg.kv_scheme if ccfg.quantized else None,
        scale=scale, interpret=interpret)
