"""Pallas TPU paged-attention decode kernel over AMS-packed (or bf16) pages.

One grid step attends one (slot, kv-head, page) cell; a ragged chunked-
prefill block ([B, c, H, hd] queries with per-query lengths) folds its c
queries into the row dimension of the same cell, so multi-token prefill
and single-token decode run the identical grid:

  * the block table rides SCALAR PREFETCH (`pltpu.PrefetchScalarGridSpec`),
    so each page's BlockSpec index_map dereferences
    ``block_table[b, i]`` BEFORE the kernel body runs — the grid pipeline
    DMAs exactly the pages the slot owns, in logical order, straight from
    the pool in HBM (this is the "walk the block table" step);
  * for AMS pools the packed planes (hi nibbles / shared-LSB words /
    per-(token, head) scales) are restored to exact lattice values in VREGs
    with the same SHIFT/AND/OR sequence as the weight kernel
    (`repro.kernels.ams_matmul.decode_codes_to_f32`) — pages are
    dequantized ON THE FLY inside the attention loop, never materialized
    in HBM;
  * a running online-softmax (m, l, acc) lives in VMEM scratch across the
    page grid dimension (innermost, "arbitrary"); keys at positions >= the
    slot's length get the additive -2e30 mask from `blockwise_attention`,
    so idle slots (length <= 0) flush to exact zeros.

The kernel iterates every block-table column; pages past a short request's
last page are fully masked compute (cheap at decode block sizes — a
length-bounded grid via scalar-prefetched page counts is the obvious next
tuning step). f32 score/accumulator math throughout, so the only deviation
from the `cache.ref` oracle is f32 reduction order.

`interpret=True` runs the exact same kernel on CPU (tier-1 tests); scratch
and block shapes here are sized for correctness-first small-model decode —
lane-width padding for odd head dims is left to Mosaic.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import get_scheme
from repro.core.kv_quant import codes_from_planes, packed_head_dim
# _CompilerParams: the CompilerParams/TPUCompilerParams rename shim
from repro.kernels.ams_matmul import _CompilerParams, decode_codes_to_f32

from .config import CacheConfig

NEG_BIG = -2e30   # additive mask; exp(NEG_BIG - NEG_CLAMP) == 0 exactly
NEG_CLAMP = -1e30


# --------------------------------------------------------------- in-kernel
def _restore_page(hi, lsb, scale, fmt, k: int, page: int, hd_p: int,
                  hd: int) -> jnp.ndarray:
    """Packed planes of one (page, kv-head) cell -> [page, hd] f32 lattice
    values. hi: [page, hd_p//2] int8, lsb: [page, gw] int32, scale [page, 1].
    """
    codes = codes_from_planes(hi, lsb, k)
    vals = decode_codes_to_f32(codes, fmt) * scale
    return vals[:, :hd]


def _row_lengths(len_ref, b, c: int, g: int):
    """Per-ROW valid-key counts [c*g, 1] for a chunked query block: the
    flattened lengths ride scalar prefetch as [B*c]; row r of the (c, g)-
    folded query block belongs to query r // g. c and g are static, so the
    gather is c scalar SMEM reads."""
    lv = jnp.stack([len_ref[b * c + j] for j in range(c)])      # [c]
    return jnp.repeat(lv, g, total_repeat_length=c * g)[:, None]


def _online_softmax_step(qf, k_page, v_page, length, i, nb, o_ref,
                         acc_ref, m_ref, l_ref, *, page: int, hd: int,
                         pv_dtype=jnp.float32):
    """One page of flash-decode accumulation. qf [rows, hd] f32 (pre-scaled;
    rows = chunk*group for ragged blocks), k_page/v_page [page, hd] f32,
    ``length`` a scalar or per-row [rows, 1] valid-key count. ``pv_dtype``
    mirrors flash_decode's ``p.astype(v.dtype)`` before the PV product
    (bf16 pools cast, AMS lattice values stay f32) so the oracle and the
    kernel round alike."""
    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_CLAMP)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    s = jax.lax.dot_general(qf, k_page, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [g, page]
    k_pos = i * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    s = s + jnp.where(k_pos < length, 0.0, NEG_BIG)

    m_prev = m_ref[:, :1]                                  # [g, 1]
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(jnp.maximum(m_prev, s.max(axis=-1, keepdims=True)),
                        NEG_CLAMP)
    p = jnp.exp(s - m_new)                                 # masked -> exact 0
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(pv_dtype), v_page.astype(pv_dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(i == nb - 1)
    def _done():
        l = l_ref[:, :1]
        out = acc_ref[...] / jnp.maximum(l, 1e-20)
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


def _kernel_bf16(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                 acc_ref, m_ref, l_ref, *, page: int, hd: int, nb: int,
                 chunk: int, g: int, pv_dtype):
    b, i = pl.program_id(0), pl.program_id(2)
    qf = q_ref[0, 0].astype(jnp.float32)
    k_page = k_ref[0, :, 0, :].astype(jnp.float32)
    v_page = v_ref[0, :, 0, :].astype(jnp.float32)
    _online_softmax_step(qf, k_page, v_page, _row_lengths(len_ref, b, chunk, g),
                         i, nb, o_ref, acc_ref, m_ref, l_ref, page=page,
                         hd=hd, pv_dtype=pv_dtype)


def _kernel_ams(bt_ref, len_ref, q_ref, khi_ref, klsb_ref, kscale_ref,
                vhi_ref, vlsb_ref, vscale_ref, o_ref, acc_ref, m_ref, l_ref,
                *, fmt, k_share: int, page: int, hd_p: int, hd: int, nb: int,
                chunk: int, g: int):
    b, i = pl.program_id(0), pl.program_id(2)
    qf = q_ref[0, 0].astype(jnp.float32)
    k_page = _restore_page(khi_ref[0, :, 0, :], klsb_ref[0, :, 0, :],
                           kscale_ref[0, :, 0, :], fmt, k_share, page, hd_p, hd)
    v_page = _restore_page(vhi_ref[0, :, 0, :], vlsb_ref[0, :, 0, :],
                           vscale_ref[0, :, 0, :], fmt, k_share, page, hd_p, hd)
    _online_softmax_step(qf, k_page, v_page, _row_lengths(len_ref, b, chunk, g),
                         i, nb, o_ref, acc_ref, m_ref, l_ref, page=page, hd=hd)


# ------------------------------------------------------------ pallas_call
def paged_attention_pallas(
    q: jnp.ndarray,              # [B, H, hd] or [B, c, H, hd] UNSCALED
    pool,                        # layer pool (cache.pool layout)
    lengths: jnp.ndarray,        # [B] int32 valid keys (<=0: idle slot);
                                 #   [B, c] per-query for chunked q
    block_table: jnp.ndarray,    # [B, max_pages_per_seq] int32
    ccfg: CacheConfig,
    *,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Paged flash-decode. Requires the group-major GQA head layout (the
    only layout the model zoo emits — see `kv_index_map`). Returns q's
    shape in q.dtype. A chunked query block folds its c queries into the
    row dimension of one grid cell ([c*g, hd] per kv head) so the ragged
    multi-token step still runs ONE kernel; per-query lengths ride the
    same scalar-prefetch stream as the block table."""
    chunked = q.ndim == 4
    if not chunked:
        q = q[:, None]
        lengths = jnp.asarray(lengths, jnp.int32)[:, None]
    B, c, H, hd = q.shape
    kv = jax.tree.leaves(pool["k"])[0].shape[2]
    if H % kv != 0:
        raise ValueError(f"H={H} not grouped over kv={kv}")
    g = H // kv
    rows = c * g
    page = ccfg.page_size
    nb = block_table.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)

    # scale in q.dtype first — the exact rounding flash_decode applies
    qf = (q * np.float32(scale).astype(q.dtype)).astype(jnp.float32)
    # [B, c, kv, g, hd] -> [B, kv, c, g, hd]: chunk-major rows per kv head
    qf = qf.reshape(B, c, kv, g, hd).transpose(0, 2, 1, 3, 4)
    qf = qf.reshape(B, kv, rows, hd)
    bt_flat = block_table.reshape(-1).astype(jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32).reshape(-1)     # [B*c]

    # index maps: scalar-prefetch refs arrive after the grid indices
    q_spec = pl.BlockSpec((1, 1, rows, hd), lambda b, h, i, bt, ln: (b, h, 0, 0))
    out_spec = pl.BlockSpec((1, 1, rows, hd), lambda b, h, i, bt, ln: (b, h, 0, 0))

    def page_spec(block_tail):
        return pl.BlockSpec(
            (1, page) + block_tail,
            lambda b, h, i, bt, ln: (bt[b * nb + i], 0, h) + (0,) * (len(block_tail) - 1))

    scratch = [pltpu.VMEM((rows, hd), jnp.float32),     # acc
               pltpu.VMEM((rows, 128), jnp.float32),    # m (col 0 live)
               pltpu.VMEM((rows, 128), jnp.float32)]    # l (col 0 live)
    grid = (B, kv, nb)
    params_kw = dict(
        out_shape=jax.ShapeDtypeStruct((B, kv, rows, hd), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )

    if ccfg.quantized:
        scheme = get_scheme(ccfg.kv_scheme)
        hd_p = packed_head_dim(hd, scheme)
        gw = pool["k"]["lsb"].shape[-1]
        kernel = functools.partial(
            _kernel_ams, fmt=scheme.base, k_share=scheme.k, page=page,
            hd_p=hd_p, hd=hd, nb=nb, chunk=c, g=g)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=grid,
            in_specs=[q_spec,
                      page_spec((1, hd_p // 2)), page_spec((1, gw)),
                      page_spec((1, 1)),
                      page_spec((1, hd_p // 2)), page_spec((1, gw)),
                      page_spec((1, 1))],
            out_specs=out_spec, scratch_shapes=scratch)
        o = pl.pallas_call(kernel, grid_spec=grid_spec, **params_kw)(
            bt_flat, lengths, qf,
            pool["k"]["hi"], pool["k"]["lsb"], pool["k"]["scale"],
            pool["v"]["hi"], pool["v"]["lsb"], pool["v"]["scale"])
    else:
        kernel = functools.partial(_kernel_bf16, page=page, hd=hd, nb=nb,
                                   chunk=c, g=g, pv_dtype=pool["v"].dtype)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=grid,
            in_specs=[q_spec, page_spec((1, hd)), page_spec((1, hd))],
            out_specs=out_spec, scratch_shapes=scratch)
        o = pl.pallas_call(kernel, grid_spec=grid_spec, **params_kw)(
            bt_flat, lengths, qf, pool["k"], pool["v"])

    # [B, kv, c, g, hd] -> [B, c, H, hd] (undo the chunk-major row fold)
    o = o.reshape(B, kv, c, g, hd).transpose(0, 2, 1, 3, 4)
    o = o.reshape(B, c, H, hd).astype(q.dtype)
    return o if chunked else o[:, 0]
