"""RecurrentGemma-9B: RG-LRU + local attention, pattern (rec, rec, attn).
[arXiv:2402.19427; unverified] — 38 layers, d_model=4096, lru_width=4096,
16 heads MQA (kv=1, head_dim=256), local window 2048, GeGLU d_ff=12288.
Sub-quadratic (bounded-window attention + recurrence): runs long_500k.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    attention="gqa",
    sliding_window=2048,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    ffn_activation="gelu_glu",
    subquadratic=True,
    source="[arXiv:2402.19427; unverified]",
)
