"""Qwen2.5-7B-Instruct: the paper's own efficiency-eval model (Table 3 uses
its (3584, 18944) MLP-down shape). Same backbone dims as Qwen2-7B."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    attention="gqa",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    ffn_activation="silu_glu",
    source="[hf:Qwen/Qwen2.5-7B-Instruct; hf]",
)
