"""Qwen1.5-4B: dense MHA (kv == heads) with QKV bias. [hf:Qwen/Qwen1.5-4B; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    attention="gqa",
    qkv_bias=True,
    ffn_activation="silu_glu",
    source="[hf:Qwen/Qwen1.5-0.5B; hf]",
)
