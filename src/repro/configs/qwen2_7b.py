"""Qwen2-7B: dense GQA with QKV bias. [arXiv:2407.10671; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    attention="gqa",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    ffn_activation="silu_glu",
    source="[arXiv:2407.10671; hf]",
)
