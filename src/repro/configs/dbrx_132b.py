"""DBRX-132B: fine-grained MoE, 16 experts top-4, GQA. [hf:databricks/dbrx-base; unverified]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    attention="gqa",
    rope_theta=500_000.0,
    num_experts=16,
    experts_per_token=4,
    ffn_activation="silu_glu",
    source="[hf:databricks/dbrx-base; unverified]",
)
