"""Model / run configuration dataclasses.

A single frozen ``ModelConfig`` drives every architecture family in the
assigned pool (dense GQA, MLA, MoE, SSM, RG-LRU hybrid, audio, VLM). The
config is static (hashable) so it can be a jit static argument.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.policy import QuantPolicy


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                  # true architectural head count
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # --- attention ---
    attention: str = "gqa"          # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0         # 0 = full attention
    # MLA (minicpm3 / deepseek-style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- FFN / MoE ---
    ffn_activation: str = "silu_glu"  # silu_glu | gelu_glu | gelu
    num_experts: int = 0
    experts_per_token: int = 0
    moe_shared_expert_ff: int = 0   # >0: llama4-style shared expert width
    moe_capacity_factor: float = 1.25

    # --- SSM (mamba1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0

    # --- hybrid (recurrentgemma) ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    lru_width: int = 0

    # --- modality frontend (stub per brief) ---
    frontend: str = "none"          # none | audio | vision
    num_prefix_embeds: int = 0      # patch/frame embeddings provided upstream

    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    subquadratic: bool = False      # eligible for long_500k

    # Reference/source tag: [source; verified-tier]
    source: str = ""

    @property
    def d_attn_out(self) -> int:
        """Width of the attention-value output entering o_proj (true heads)."""
        if self.attention == "mla":
            return self.num_heads * self.v_head_dim
        return self.num_heads * self.head_dim

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    def reduced(self, **over) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            num_layers=min(self.num_layers, 2 if not self.block_pattern else len(self.block_pattern)),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
        )
        if self.attention == "mla":
            small.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=16,
                         qk_rope_dim=16, v_head_dim=32)
        if self.num_experts:
            small.update(num_experts=4,
                         experts_per_token=min(self.experts_per_token, 2))
        if self.moe_shared_expert_ff:
            small.update(moe_shared_expert_ff=256)
        if self.ssm_state:
            small.update(ssm_state=8, dt_rank=8)
        if self.lru_width:
            small.update(lru_width=128)
        if self.sliding_window:
            small.update(sliding_window=64)
        if self.num_prefix_embeds:
            small.update(num_prefix_embeds=8)
        small.update(over)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Everything around the model: shapes, quantization, execution knobs."""

    model: ModelConfig
    seq_len: int = 4096
    global_batch: int = 256
    mode: str = "train"             # train | prefill | decode
    # training
    microbatch: int = 0             # 0 = auto (one sample per data shard)
    remat: bool = True
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    grad_clip: float = 1.0
    grad_compression: str = "none"  # none | int8_ag
    # serving (the paper's regime)
    quant: Optional[QuantPolicy] = None
    # attention blocking
    attn_block_kv: int = 1024
    # sharding
    fsdp: bool = True

    @property
    def quantized(self) -> bool:
        return self.quant is not None and self.quant.scheme != "fp16"
