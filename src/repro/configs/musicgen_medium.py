"""MusicGen-medium backbone: decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf] — the EnCodec frontend is a stub per the brief; the
backbone consumes audio-token ids (vocab 2048) directly. Plain GELU MLP.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    attention="gqa",
    ffn_activation="gelu",
    frontend="audio",
    source="[arXiv:2306.05284; hf]",
)
