"""Architecture registry: one config per assigned architecture (+ paper's)."""

from __future__ import annotations

import importlib
from typing import Dict, List

from .base import ModelConfig, RunConfig  # noqa: F401

_ARCH_MODULES = [
    "minicpm3_4b",
    "qwen2_7b",
    "qwen1_5_4b",
    "deepseek_coder_33b",
    "dbrx_132b",
    "llama4_scout_17b_16e",
    "falcon_mamba_7b",
    "musicgen_medium",
    "recurrentgemma_9b",
    "internvl2_1b",
    "qwen2_5_7b",  # the paper's own evaluation model
]

_REGISTRY: Dict[str, ModelConfig] = {}


def _load():
    if _REGISTRY:
        return
    for m in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{m}")
        cfg = mod.CONFIG
        _REGISTRY[cfg.name] = cfg


def get_config(name: str) -> ModelConfig:
    _load()
    return _REGISTRY[name]


def list_archs(assigned_only: bool = True) -> List[str]:
    _load()
    names = list(_REGISTRY)
    if assigned_only:
        names = [n for n in names if n != "qwen2.5-7b"]
    return names
