"""Falcon-Mamba-7B: attention-free Mamba-1 SSM. [arXiv:2410.05355; unverified]

64 layers, d_model=4096, d_inner=8192 (expand 2), ssm_state=16, conv=4,
dt_rank = d_model/16 = 256. Sub-quadratic: runs the long_500k shape.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=65024,
    attention="none",
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    dt_rank=256,
    subquadratic=True,
    source="[arXiv:2410.05355; unverified]",
)
