"""InternVL2-1B backbone (Qwen2-0.5B-style LM): the InternViT frontend is a
stub per the brief — input_specs() provides 256 precomputed patch embeddings
prepended to the text sequence. [arXiv:2404.16821; hf]
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    attention="gqa",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    ffn_activation="silu_glu",
    frontend="vision",
    num_prefix_embeds=256,
    source="[arXiv:2404.16821; hf]",
)
