"""MiniCPM3-4B: dense with Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B; hf] — 62L, d_model=2560, 40 heads (kv=40 at the
architectural level; MLA compresses KV to kv_lora_rank=256 + 32 rope dims),
d_ff=6400, vocab=73448. MLA dims follow the HF config: q_lora_rank=768,
kv_lora_rank=256, qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    ffn_activation="silu_glu",
    source="[hf:openbmb/MiniCPM3-4B; hf]",
)
