"""Llama-4-Scout-17B-16E: MoE 16 experts top-1 + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    attention="gqa",
    rope_theta=500_000.0,
    num_experts=16,
    experts_per_token=1,
    moe_shared_expert_ff=8192,
    ffn_activation="silu_glu",
    source="[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
)
