"""Deterministic synthetic LM data pipeline (offline container — no corpora).

Produces reproducible pseudo-text token streams with Zipfian unigram
statistics plus planted short-range structure (bigram copies), so a small
model trained on it shows a real, monotonically improving loss — enough for
the end-to-end training driver and the format-accuracy benchmark proxy.

The pipeline is sharded: each data-parallel host slice draws only its own
batch shard (host_id, num_hosts), with a seekable stateless index -> batch
mapping (step, shard) -> tokens, which is what makes checkpoint/restart and
elastic re-sharding exact: no iterator state to save beyond the step.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.2
    copy_prob: float = 0.3     # planted structure: token repeats 8 back
    copy_dist: int = 8


class SyntheticLM:
    """Stateless, seekable synthetic corpus."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed Zipf-ish unigram distribution over the true vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** cfg.zipf_a
        self._p = (p / p.sum()).astype(np.float64)

    def batch(self, step: int, shard: int = 0, num_shards: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        """Return (tokens, targets) for one step/shard: [B_loc, S] int32."""
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        b_loc = cfg.global_batch // num_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, shard]))
        toks = rng.choice(cfg.vocab_size, size=(b_loc, cfg.seq_len + 1),
                          p=self._p).astype(np.int32)
        # plant copy structure: with prob copy_prob, token t = token t-d
        d = cfg.copy_dist
        mask = rng.random((b_loc, cfg.seq_len + 1)) < cfg.copy_prob
        mask[:, :d] = False
        idx = np.arange(cfg.seq_len + 1)
        toks = np.where(mask, toks[:, idx - d], toks)
        return toks[:, :-1], toks[:, 1:]

    def iterate(self, start_step: int = 0, shard: int = 0,
                num_shards: int = 1) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, shard, num_shards)
            step += 1


def prefix_embeds_stub(cfg_model, batch: int, seed: int = 0) -> Optional[np.ndarray]:
    """Deterministic frontend stub: precomputed frame/patch embeddings."""
    if not cfg_model.num_prefix_embeds:
        return None
    rng = np.random.default_rng(seed)
    return rng.standard_normal(
        (batch, cfg_model.num_prefix_embeds, cfg_model.d_model)
    ).astype(np.float32)
