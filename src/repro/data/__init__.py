from .pipeline import DataConfig, SyntheticLM, prefix_embeds_stub  # noqa: F401
