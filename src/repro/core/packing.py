"""Ahead-of-time weight packing into 32-bit-aligned bit-planes (paper §3.2/3.3).

TPU adaptation of TC-FPx-style prepacking: instead of per-thread uint16
segments we store *planes* of int32 words laid out ``[K_packed, N]`` so that
Pallas BlockSpecs tile them with fully regular HBM->VMEM DMAs:

  * ``hi``  plane — the per-weight unshared bits (code >> 1 when k > 1, the
              full code when k == 1), ``per_word = 32 // hi_bits`` consecutive
              K-positions per int32 word.
  * ``lsb`` plane — one bit per k-group (absent when k == 1); 32 groups per
              int32 word.
  * ``fp533`` fused container — the paper's flagship special case: FP5.33
              (e2m3, k=3) packs 3x5-bit high segments + 1 shared LSB into each
              half-word, i.e. 6 weights + 2 shared bits per int32, with ZERO
              padding waste. One memory stream instead of two.

K is zero-padded to the packing block; code 0 decodes to +0 so padded rows
are exact no-ops in the matmul (activations are also zero-padded).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .formats import AMSFormat


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class PackLayout:
    """Static description of how a scheme is packed."""

    scheme: AMSFormat
    container: str  # "planes" | "fp533"
    hi_bits: int
    per_word: int  # hi codes per int32 word
    k_block: int  # K must be padded to a multiple of this

    @property
    def lsb_groups_per_word(self) -> int:
        return 32

    def padded_k(self, K: int) -> int:
        return _ceil_to(K, self.k_block)

    def hi_rows(self, K: int) -> int:
        return self.padded_k(K) // self.per_word

    def lsb_rows(self, K: int) -> int:
        if self.scheme.k == 1 or self.container == "fp533":
            return 0
        return self.padded_k(K) // (32 * self.scheme.k)

    def packed_bytes(self, K: int, N: int) -> int:
        return 4 * N * (self.hi_rows(K) + self.lsb_rows(K))

    def effective_bits(self, K: int, N: int) -> float:
        return self.packed_bytes(K, N) * 8.0 / (K * N)


def make_layout(scheme: AMSFormat, container: Optional[str] = None) -> PackLayout:
    k = scheme.k
    if container is None:
        container = "fp533" if (k == 3 and scheme.base.name == "e2m3") else "planes"
    if container == "fp533":
        assert k == 3 and scheme.base.total_bits == 6
        # 6 weights (2 groups) per int32; K block must also be a multiple of 6.
        return PackLayout(scheme, "fp533", hi_bits=5, per_word=6, k_block=6)
    hi_bits = scheme.base.total_bits - (1 if k > 1 else 0)
    per_word = 32 // hi_bits
    if k == 1:
        k_block = per_word
    else:
        k_block = math.lcm(per_word, 32 * k)
    return PackLayout(scheme, container, hi_bits, per_word, k_block)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PackedWeight:
    """Packed quantized weight planes + channel scales (a JAX pytree).

    Shapes: hi [hi_rows, N] int32; lsb [lsb_rows, N] int32 (or shape [0, N]);
    scale [N] f32. ``layout`` / ``K`` / ``N`` are static metadata.
    """

    hi: jnp.ndarray
    lsb: jnp.ndarray
    scale: jnp.ndarray
    layout: PackLayout = dataclasses.field(metadata=dict(static=True))
    K: int = dataclasses.field(metadata=dict(static=True))
    N: int = dataclasses.field(metadata=dict(static=True))


def pack(codes: jnp.ndarray, scale: jnp.ndarray, scheme: AMSFormat,
         container: Optional[str] = None) -> PackedWeight:
    """Pack full codes [K, N] (bit0 already shared per group) into planes."""
    layout = make_layout(scheme, container)
    K, N = codes.shape
    Kp = layout.padded_k(K)
    codes = jnp.pad(codes.astype(jnp.int32), ((0, Kp - K), (0, 0)))
    k = scheme.k

    if layout.container == "fp533":
        hi = (codes >> 1).reshape(Kp // 6, 6, N)
        lsb = (codes & 1).reshape(Kp // 3, 3, N)[:, 0, :].reshape(Kp // 6, 2, N)
        word = jnp.zeros((Kp // 6, N), jnp.int32)
        # half h (bits 16h..16h+15): w0|w1<<5|w2<<10|lsb<<15
        for h in range(2):
            half = (hi[:, 3 * h] | (hi[:, 3 * h + 1] << 5)
                    | (hi[:, 3 * h + 2] << 10) | (lsb[:, h] << 15))
            word = word | (half << (16 * h))
        return PackedWeight(word, jnp.zeros((0, N), jnp.int32),
                            scale.astype(jnp.float32), layout, K, N)

    hi_codes = (codes >> 1) if k > 1 else codes
    pw = layout.per_word
    hi_g = hi_codes.reshape(Kp // pw, pw, N)
    shifts = (jnp.arange(pw, dtype=jnp.int32) * layout.hi_bits)[None, :, None]
    hi = jnp.bitwise_or.reduce(hi_g << shifts, axis=1).astype(jnp.int32)

    if k > 1:
        bits = (codes & 1).reshape(Kp // k, k, N)[:, 0, :]  # one bit per group
        bits_g = bits.reshape(Kp // (32 * k), 32, N)
        bshift = jnp.arange(32, dtype=jnp.int32)[None, :, None]
        lsb = jnp.bitwise_or.reduce(bits_g << bshift, axis=1).astype(jnp.int32)
    else:
        lsb = jnp.zeros((0, N), jnp.int32)
    return PackedWeight(hi, lsb, scale.astype(jnp.float32), layout, K, N)


def unpack(pw: PackedWeight) -> jnp.ndarray:
    """Reverse of pack(): full signed codes [K, N] (reference path & tests)."""
    layout = pw.layout
    k = layout.scheme.k
    Kp = layout.padded_k(pw.K)
    N = pw.N

    if layout.container == "fp533":
        halves = jnp.stack(
            [(pw.hi >> (16 * h)) & 0xFFFF for h in range(2)], axis=1
        )  # [Kp//6, 2, N]
        w_hi = jnp.stack(
            [(halves >> (5 * j)) & 0x1F for j in range(3)], axis=2
        )  # [Kp//6, 2, 3, N]
        lsb = (halves >> 15) & 1  # [Kp//6, 2, N]
        codes = (w_hi << 1) | lsb[:, :, None, :]
        return codes.reshape(Kp, N)[: pw.K]

    pwords = layout.per_word
    mask = (1 << layout.hi_bits) - 1
    hi = jnp.stack(
        [(pw.hi >> (layout.hi_bits * j)) & mask for j in range(pwords)], axis=1
    ).reshape(Kp, N)
    if k == 1:
        return hi[: pw.K]
    gbits = jnp.stack([(pw.lsb >> j) & 1 for j in range(32)], axis=1).reshape(
        Kp // k, N
    )
    lsb_full = jnp.repeat(gbits, k, axis=0)
    return ((hi << 1) | lsb_full)[: pw.K]
