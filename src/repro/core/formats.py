"""Floating-point format definitions for AMS-Quant.

A low-bit FP format is ``s | E (exp_bits) | M (man_bits)`` with no Inf/NaN:
per the paper (§2.2, following OCP MX), all-ones exponents decode to regular
values because the quantized weights are always dequantized back to a wide
type before use.

Codes are plain non-negative integers (int32 in JAX) laid out as
``sign << (e+m) | E << m | M``. Bit 0 is the least-significant mantissa bit —
the bit that AMS-Quant shares across a group of ``k`` weights.
"""

from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Dict

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FPFormat:
    """A miniature IEEE-like floating-point format (no Inf/NaN)."""

    name: str
    exp_bits: int
    man_bits: int
    bias: int

    @property
    def total_bits(self) -> int:
        return 1 + self.exp_bits + self.man_bits

    @property
    def code_bits(self) -> int:  # bits of the unsigned magnitude code
        return self.exp_bits + self.man_bits

    @property
    def num_mag_codes(self) -> int:
        return 1 << self.code_bits

    @property
    def max_normal(self) -> float:
        e_max = (1 << self.exp_bits) - 1
        m_max = (1 << self.man_bits) - 1
        return 2.0 ** (e_max - self.bias) * (1.0 + m_max / (1 << self.man_bits))

    @property
    def min_subnormal(self) -> float:
        return 2.0 ** (1 - self.bias) / (1 << self.man_bits)

    def decode_mag(self, mag_codes: np.ndarray) -> np.ndarray:
        """Numpy decode of unsigned magnitude codes -> float64 magnitudes."""
        mag_codes = np.asarray(mag_codes)
        m = mag_codes & ((1 << self.man_bits) - 1)
        e = mag_codes >> self.man_bits
        frac = m / (1 << self.man_bits)
        normal = 2.0 ** (e - self.bias) * (1.0 + frac)
        sub = 2.0 ** (1 - self.bias) * frac
        return np.where(e == 0, sub, normal)


def code_to_value(fmt: FPFormat, codes: jnp.ndarray) -> jnp.ndarray:
    """Vectorized jnp decode of full (signed) codes -> float32 values.

    This is the *reference* restoration path; the Pallas kernel reimplements
    it with bit-assembly (see kernels/ams_matmul.py) and is tested against it.
    """
    codes = codes.astype(jnp.int32)
    m_mask = (1 << fmt.man_bits) - 1
    e_mask = (1 << fmt.exp_bits) - 1
    M = codes & m_mask
    E = (codes >> fmt.man_bits) & e_mask
    S = (codes >> (fmt.man_bits + fmt.exp_bits)) & 1
    frac = M.astype(jnp.float32) * np.float32(1.0 / (1 << fmt.man_bits))
    # ldexp is exact (pure exponent manipulation); exp2 is transcendental and
    # can be off by 1 ulp on some backends, which would break bit-exactness.
    normal = jnp.ldexp(1.0 + frac, E - fmt.bias)
    sub = np.float32(2.0 ** (1 - fmt.bias)) * frac
    mag = jnp.where(E == 0, sub, normal)
    return jnp.where(S == 1, -mag, mag)


@lru_cache(maxsize=None)
def mag_table(fmt: FPFormat) -> np.ndarray:
    """Sorted float32 magnitudes of all unsigned codes (monotone in code)."""
    vals = fmt.decode_mag(np.arange(fmt.num_mag_codes))
    # IEEE-style layouts are monotone in the magnitude code by construction.
    assert np.all(np.diff(vals) > 0), f"non-monotone format {fmt.name}"
    return vals.astype(np.float32)


@lru_cache(maxsize=None)
def mag_midpoints(fmt: FPFormat) -> np.ndarray:
    t = mag_table(fmt).astype(np.float64)
    return ((t[:-1] + t[1:]) / 2.0).astype(np.float32)


@lru_cache(maxsize=None)
def lsb_subgrid(fmt: FPFormat, lsb: int):
    """(codes, mags, midpoints) of the sub-grid whose mantissa LSB == lsb.

    Used by the 'requantize' adaptive-search strategy: re-round each weight to
    the nearest representable value *within* the shared-LSB sub-lattice.
    """
    codes = np.arange(fmt.num_mag_codes)
    sel = codes[(codes & 1) == lsb]
    mags = fmt.decode_mag(sel).astype(np.float64)
    mids = ((mags[:-1] + mags[1:]) / 2.0).astype(np.float32)
    return sel.astype(np.int32), mags.astype(np.float32), mids


def _std_bias(e: int) -> int:
    return (1 << (e - 1)) - 1


# ---------------------------------------------------------------------------
# Registry. Biases follow OCP MX / the paper's Table 1 (bias = 2^(e-1)-1).
# ---------------------------------------------------------------------------
FORMATS: Dict[str, FPFormat] = {}
for _e, _m in [(2, 1), (2, 2), (2, 3), (3, 2), (3, 3), (4, 3), (5, 2)]:
    _f = FPFormat(f"e{_e}m{_m}", _e, _m, _std_bias(_e))
    FORMATS[_f.name] = _f


def get_format(name: str) -> FPFormat:
    return FORMATS[name]


@dataclasses.dataclass(frozen=True)
class AMSFormat:
    """An AMS-Quant scheme: base format + mantissa-sharing group size k.

    k == 1 means plain RTN at the base format (no sharing).
    Effective bits/weight = (total_bits - 1) + 1/k when k > 1.
    """

    base: FPFormat
    k: int = 1

    @property
    def effective_bits(self) -> float:
        if self.k == 1:
            return float(self.base.total_bits)
        return (self.base.total_bits - 1) + 1.0 / self.k

    @property
    def name(self) -> str:
        if self.k == 1:
            return f"fp{self.base.total_bits}-{self.base.name}"
        eb = self.effective_bits
        return f"fp{eb:.4g}-{self.base.name}-k{self.k}"


# The schemes evaluated in the paper (Table 2 / Table 3), by friendly name.
SCHEMES: Dict[str, AMSFormat] = {
    "fp8": AMSFormat(get_format("e4m3"), 1),
    "fp6-e2m3": AMSFormat(get_format("e2m3"), 1),
    "fp6-e3m2": AMSFormat(get_format("e3m2"), 1),
    "fp5.33-e2m3": AMSFormat(get_format("e2m3"), 3),
    "fp5-e2m2": AMSFormat(get_format("e2m2"), 1),
    "fp4.5-e2m2": AMSFormat(get_format("e2m2"), 2),
    "fp4.33-e2m2": AMSFormat(get_format("e2m2"), 3),
    "fp4.25-e2m2": AMSFormat(get_format("e2m2"), 4),
    "fp4-e2m1": AMSFormat(get_format("e2m1"), 1),
}


def get_scheme(name: str) -> AMSFormat:
    return SCHEMES[name]
