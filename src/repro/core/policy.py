"""Quantization policy: which tensors get AMS-quantized and how.

Mirrors deployment practice (and the paper's evaluation): large projection
matrices are quantized; tiny/accuracy-critical tensors (MoE routers, norms,
SSM recurrence params, biases) stay in high precision.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    scheme: str = "fp5.33-e2m3"      # key into core.formats.SCHEMES
    strategy: str = "set_lsb"        # 'set_lsb' (paper) | 'requantize' (ours)
    impl: str = "ref"                # 'ref' | 'pallas' | 'pallas_interpret' | 'fused_ref'
    quantize_embeddings: bool = False
    quantize_lm_head: bool = False
    min_elements: int = 1 << 16      # skip tensors smaller than this (routers…)

    def wants(self, name: str, shape) -> bool:
        """Should tensor `name` with `shape` be quantized?"""
        if len(shape) != 2:
            return False
        n = shape[0] * shape[1]
        if n < self.min_elements:
            return False
        if "router" in name or "gate_proj_router" in name:
            return False
        if "embed" in name and not self.quantize_embeddings:
            return False
        if "lm_head" in name and not self.quantize_lm_head:
            return False
        return True


FP16_POLICY = QuantPolicy(scheme="fp16")  # sentinel: no quantization


def is_fp16(policy: QuantPolicy) -> bool:
    return policy.scheme == "fp16"
