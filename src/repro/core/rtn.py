"""Channel-wise Round-To-Nearest floating-point quantization (paper §2.1/§3.1).

Weights are stored ``[K, N]`` (in_features, out_features). Quantization is
per *output channel* n: ``s_q[n] = max_k |W[k, n]| / max_normal(fmt)``;
AMS mantissa sharing later groups along the *input-channel* axis K (paper
§3.1, "Mantissa Sharing ... along the input-channel dimension").

Rounding is round-to-nearest with ties away from zero (the argmin in the
paper's Round() is tie-agnostic; ties have measure ~0 for real weights).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .formats import FPFormat, code_to_value, mag_midpoints, mag_table


def channel_scales(w: jnp.ndarray, fmt: FPFormat) -> jnp.ndarray:
    """Per-output-channel scales s_q[n] = max|W[:, n]| / max_normal."""
    amax = jnp.max(jnp.abs(w), axis=0)
    scale = amax / np.float32(fmt.max_normal)
    return jnp.where(scale == 0, jnp.float32(1.0), scale).astype(jnp.float32)


def nearest_mag_codes(x_abs: jnp.ndarray, fmt: FPFormat) -> jnp.ndarray:
    """Nearest unsigned-magnitude code for |normalized| values (clipped)."""
    mids = jnp.asarray(mag_midpoints(fmt))
    # searchsorted over the (tiny: <=2^code_bits-1) midpoint table.
    idx = jnp.searchsorted(mids, x_abs.astype(jnp.float32), side="right")
    return idx.astype(jnp.int32)


def quantize_rtn(w: jnp.ndarray, fmt: FPFormat, scale: jnp.ndarray | None = None):
    """RTN-quantize ``w`` -> (codes int32, scale f32[N]).

    codes layout: sign << (e+m) | magnitude_code.
    """
    w = w.astype(jnp.float32)
    if scale is None:
        scale = channel_scales(w, fmt)
    wn = w / scale
    mag = nearest_mag_codes(jnp.abs(wn), fmt)
    sign = (wn < 0).astype(jnp.int32)
    codes = mag | (sign << fmt.code_bits)
    return codes, scale


def dequantize(codes: jnp.ndarray, fmt: FPFormat, scale: jnp.ndarray) -> jnp.ndarray:
    """DeQ(W) = decode(codes) * s_q (paper eqn. 2)."""
    return code_to_value(fmt, codes) * scale


def quantize_dequantize(w: jnp.ndarray, fmt: FPFormat) -> jnp.ndarray:
    """Fake-quant round trip (used by accuracy benchmarks & baselines)."""
    codes, scale = quantize_rtn(w, fmt)
    return dequantize(codes, fmt, scale)


def table_values(fmt: FPFormat) -> np.ndarray:
    """All representable signed values (numpy, for tests/analysis)."""
    t = mag_table(fmt)
    return np.concatenate([-t[::-1], t])
