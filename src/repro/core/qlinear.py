"""QuantizedLinear: the deployable AMS-Quant linear layer.

Holds packed planes + channel scales. ``apply`` dispatches between:
  * ``ref``     — pure-jnp unpack -> bit decode -> matmul (XLA path; also the
                  oracle the Pallas kernel is tested against).
  * ``pallas``  — fused Pallas kernel (kernels/ams_matmul.py): packed words
                  stream HBM->VMEM, bit-restore to bf16 in VREGs, MXU matmul.
                  On CPU runtimes use ``pallas_interpret``.
  * ``fused_ref`` — jnp path shaped to encourage XLA to fuse dequant into the
                  consumer (K-blocked scan), used as a dry-run stand-in with
                  packed-byte traffic.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .ams import ams_quantize
from .formats import AMSFormat, code_to_value
from .packing import PackedWeight, pack, unpack


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QuantizedLinear:
    """Packed AMS-quantized linear weight (+ optional fp bias)."""

    packed: PackedWeight
    bias: Optional[jnp.ndarray]  # [N] bf16/f32 or None

    @property
    def scheme(self) -> AMSFormat:
        return self.packed.layout.scheme

    @property
    def in_features(self) -> int:
        return self.packed.K

    @property
    def out_features(self) -> int:
        return self.packed.N


def quantize_linear(
    w: jnp.ndarray,
    scheme: AMSFormat,
    bias: Optional[jnp.ndarray] = None,
    strategy: str = "set_lsb",
    container: Optional[str] = None,
) -> QuantizedLinear:
    """Offline PTQ of a [K, N] weight into a QuantizedLinear.

    K is zero-padded up to the packing block (padded rows quantize to code 0
    == +0.0 and multiply zero-padded activations, so they are exact no-ops);
    the true K is kept in the PackedWeight.
    """
    from .packing import make_layout

    K, _ = w.shape
    layout = make_layout(scheme, container)
    Kp = layout.padded_k(K)
    wp = jnp.pad(w.astype(jnp.float32), ((0, Kp - K), (0, 0)))
    codes, scale = ams_quantize(wp, scheme, strategy)
    packed = pack(codes, scale, scheme, container)
    packed = dataclasses.replace(packed, K=K)
    return QuantizedLinear(packed, bias)


def dequantize_weight(q: QuantizedLinear, dtype=jnp.bfloat16) -> jnp.ndarray:
    """Materialize the [K, N] dequantized weight (reference/debug)."""
    codes = unpack(q.packed)
    w = code_to_value(q.scheme.base, codes) * q.packed.scale
    return w.astype(dtype)


def apply(q: QuantizedLinear, x: jnp.ndarray, impl: str = "ref") -> jnp.ndarray:
    """y = x @ DeQ(W) (+ bias). x: [..., K]."""
    if impl == "ref":
        w = dequantize_weight(q, dtype=x.dtype)
        y = x @ w
    elif impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops  # lazy: keeps core importable standalone

        y = ops.ams_matmul(x, q.packed, interpret=(impl == "pallas_interpret"))
        y = y.astype(x.dtype)
    elif impl == "fused_ref":
        from repro.kernels import ref  # lazy

        y = ref.ams_matmul_blocked(x, q.packed).astype(x.dtype)
    else:
        raise ValueError(f"unknown impl {impl!r}")
    if q.bias is not None:
        y = y + q.bias.astype(y.dtype)
    return y
