"""Mantissa-bit sharing + Adaptive Searching (paper §3.1).

Given RTN codes for a ``[K, N]`` weight, group ``k`` consecutive codes along
the input-channel axis K and force their mantissa LSB (code bit 0) to a single
shared value ``m0``; ``m0`` is chosen per group to minimize the MSE against
the original weights:

    m0* = argmin_{m0 in {0,1}}  sum_i (DeQ(G(code_i, m0)) - w_i)^2

Two strategies:
  * ``set_lsb``      — the paper's formulation: keep RTN's high bits, only
                       overwrite bit 0 with the candidate m0.
  * ``requantize``   — beyond-paper refinement: for each candidate m0,
                       re-round every weight to its nearest representable
                       value on the LSB==m0 sub-lattice, then pick the better
                       group. Error is <= set_lsb by construction.

Because the channel scale is constant within a column, the argmin over the
scaled MSE equals the argmin over normalized-weight MSE, so all math here is
done on normalized weights (w / s_q).
"""

from __future__ import annotations

import jax.numpy as jnp

from .formats import AMSFormat, FPFormat, code_to_value, lsb_subgrid
from .rtn import channel_scales, quantize_rtn


def _group_err(vals: jnp.ndarray, wn: jnp.ndarray, k: int) -> jnp.ndarray:
    """Sum of squared errors per (group, column): [K/k, N]."""
    K, N = wn.shape
    d = (vals - wn) ** 2
    return d.reshape(K // k, k, N).sum(axis=1)


def _subgrid_codes(wn: jnp.ndarray, fmt: FPFormat, lsb: int) -> jnp.ndarray:
    """Nearest code to each normalized weight on the LSB==lsb sub-lattice."""
    sel, _, mids = lsb_subgrid(fmt, lsb)
    idx = jnp.searchsorted(jnp.asarray(mids), jnp.abs(wn).astype(jnp.float32),
                           side="right")
    mag = jnp.asarray(sel)[idx]
    sign = (wn < 0).astype(jnp.int32)
    return mag | (sign << fmt.code_bits)


def share_mantissa(
    codes: jnp.ndarray,
    wn: jnp.ndarray,
    fmt: FPFormat,
    k: int,
    strategy: str = "set_lsb",
) -> jnp.ndarray:
    """Return codes whose bit-0 is constant within each k-group along axis 0.

    ``wn`` is the *normalized* original weight (w / s_q), same shape as codes.
    """
    if k == 1:
        return codes
    K, N = codes.shape
    if K % k != 0:
        raise ValueError(f"K={K} not divisible by group size k={k}")

    if strategy == "set_lsb":
        cand0 = codes & ~jnp.int32(1)
        cand1 = codes | jnp.int32(1)
    elif strategy == "requantize":
        cand0 = _subgrid_codes(wn, fmt, 0)
        cand1 = _subgrid_codes(wn, fmt, 1)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")

    err0 = _group_err(code_to_value(fmt, cand0), wn, k)
    err1 = _group_err(code_to_value(fmt, cand1), wn, k)
    pick1 = (err1 < err0)[:, None, :]  # [K/k, 1, N]
    out = jnp.where(
        jnp.broadcast_to(pick1, (K // k, k, N)).reshape(K, N),
        cand1,
        cand0,
    )
    return out.astype(jnp.int32)


def ams_quantize(
    w: jnp.ndarray,
    scheme: AMSFormat,
    strategy: str = "set_lsb",
    scale: jnp.ndarray | None = None,
):
    """Full AMS-Quant: channel-wise RTN -> grouped LSB sharing.

    Returns (codes int32 [K, N], scale f32 [N]). With scheme.k == 1 this is
    plain RTN at the base format (the paper's baselines).
    """
    w = w.astype(jnp.float32)
    fmt = scheme.base
    if scale is None:
        scale = channel_scales(w, fmt)
    codes, _ = quantize_rtn(w, fmt, scale=scale)
    if scheme.k > 1:
        codes = share_mantissa(codes, w / scale, fmt, scheme.k, strategy)
    return codes, scale


def ams_quantize_dequantize(
    w: jnp.ndarray, scheme: AMSFormat, strategy: str = "set_lsb"
) -> jnp.ndarray:
    """Fake-quant round trip through the AMS scheme (for accuracy evals)."""
    codes, scale = ams_quantize(w, scheme, strategy)
    return code_to_value(scheme.base, codes) * scale


def shared_lsb_bits(codes: jnp.ndarray, k: int) -> jnp.ndarray:
    """Extract the per-group shared bit: [K/k, N]. Validates group agreement."""
    K, N = codes.shape
    g = (codes & 1).reshape(K // k, k, N)
    return g[:, 0, :]
