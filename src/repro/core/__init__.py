"""AMS-Quant core: formats, RTN, mantissa sharing, packing, quantized linear."""

from .formats import (  # noqa: F401
    AMSFormat,
    FORMATS,
    FPFormat,
    SCHEMES,
    code_to_value,
    get_format,
    get_scheme,
)
from .rtn import (  # noqa: F401
    channel_scales,
    dequantize,
    quantize_dequantize,
    quantize_rtn,
)
from .ams import (  # noqa: F401
    ams_quantize,
    ams_quantize_dequantize,
    share_mantissa,
    shared_lsb_bits,
)
from .packing import PackedWeight, PackLayout, make_layout, pack, unpack  # noqa: F401
from .qlinear import (  # noqa: F401
    QuantizedLinear,
    apply,
    dequantize_weight,
    quantize_linear,
)
