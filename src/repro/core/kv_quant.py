"""AMS-KV: mantissa-bit sharing applied to the KV cache (beyond-paper).

§Perf pair 3 showed that for MHA archs at 32k context the decode roofline is
KV-cache-bound, not weight-bound — the paper's weight-only scope saturates.
The same AMS math transfers directly: quantize each inserted K/V vector to
e2m2 along the head_dim axis with one scale per (token, head) (the exact
analogue of channel-wise RTN) and share each mantissa LSB across k=4
neighbors chosen by the paper's adaptive MSE search. Storage per value:

    4-bit hi nibbles (2/int8) + 1 shared LSB per 4 values + f32 scale/head
    = 4.25 bits + 32/head  ->  3.7x smaller cache than bf16.

Each token is quantized ONCE at insert (no repacking of history), so decode
cost is one dequant pass over the cache — on TPU that rides the same
restore-before-MXU pattern as the weight kernel.

This module is the validated numerical core + packed container; wiring into
`flash_decode` is the documented integration point (DESIGN.md §Future).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .ams import share_mantissa
from .formats import AMSFormat, code_to_value, get_format, get_scheme
from .rtn import quantize_rtn

KV_SCHEME = get_scheme("fp4.25-e2m2")


def quantize_kv(x: jnp.ndarray, scheme: AMSFormat = KV_SCHEME,
                strategy: str = "set_lsb"):
    """Quantize [..., hd] vectors -> packed planes.

    Returns dict: hi int8 [..., hd/2] (two 4-bit codes per byte),
    lsb int32 [..., hd/128] bitplane (one bit per k-group), scale f32 [..., 1].
    Requires hd % (32 * k) == 0 (hd=64/128/256 all qualify for k=4... hd%128;
    for hd in {64, 96} the lsb plane packs ceil groups into one int32).
    """
    fmt = scheme.base
    k = scheme.k
    hd = x.shape[-1]
    assert hd % k == 0
    lead = x.shape[:-1]
    x2 = x.reshape(-1, hd).astype(jnp.float32)   # [M, hd]
    # channel-wise = per-vector scale: treat vectors as columns
    wt = x2.T                                    # [hd, M]
    codes, scale = quantize_rtn(wt, fmt)         # codes [hd, M], scale [M]
    codes = share_mantissa(codes, wt / scale, fmt, k, strategy)
    codes = codes.T                              # [M, hd]

    hi = (codes >> 1).astype(jnp.uint8)          # 4-bit segments
    hi_packed = (hi[:, 0::2] | (hi[:, 1::2] << 4)).astype(jnp.int8)
    g = hd // k                                  # groups per vector
    gw = -(-g // 32)                             # int32 words for the bitplane
    bits = (codes[:, ::k] & 1)                   # [M, g]
    bits = jnp.pad(bits, ((0, 0), (0, gw * 32 - g)))
    shifts = jnp.arange(32, dtype=jnp.int32)[None, None, :]
    lsb = jnp.bitwise_or.reduce(
        (bits.reshape(-1, gw, 32) << shifts), axis=-1).astype(jnp.int32)
    return {
        "hi": hi_packed.reshape(*lead, hd // 2),
        "lsb": lsb.reshape(*lead, gw),
        "scale": scale.reshape(*lead, 1).astype(jnp.float32),
    }


def dequantize_kv(q, hd: int, scheme: AMSFormat = KV_SCHEME,
                  dtype=jnp.bfloat16) -> jnp.ndarray:
    """Packed planes -> [..., hd] values (bit restore, same as the kernel)."""
    fmt = scheme.base
    k = scheme.k
    lead = q["hi"].shape[:-1]
    hi = q["hi"].reshape(-1, hd // 2).astype(jnp.int32) & 0xFF
    lo_n = hi & 0xF
    hi_n = (hi >> 4) & 0xF
    codes_hi = jnp.stack([lo_n, hi_n], axis=-1).reshape(-1, hd)
    g = hd // k
    gw = q["lsb"].shape[-1]
    lsb_words = q["lsb"].reshape(-1, gw)
    bits = jnp.stack([(lsb_words >> j) & 1 for j in range(32)],
                     axis=-1).reshape(-1, gw * 32)[:, :g]
    lsb_full = jnp.repeat(bits, k, axis=-1)
    codes = (codes_hi << 1) | lsb_full
    vals = code_to_value(fmt, codes) * q["scale"].reshape(-1, 1)
    return vals.reshape(*lead, hd).astype(dtype)


def kv_bytes(hd: int, scheme: AMSFormat = KV_SCHEME) -> Tuple[int, int]:
    """(packed bytes per vector, bf16 bytes per vector)."""
    g = hd // scheme.k
    gw = -(-g // 32)
    return hd // 2 + 4 * gw + 4, 2 * hd
