"""AMS-KV: mantissa-bit sharing applied to the KV cache (beyond-paper).

§Perf pair 3 showed that for MHA archs at 32k context the decode roofline is
KV-cache-bound, not weight-bound — the paper's weight-only scope saturates.
The same AMS math transfers directly: quantize each inserted K/V vector to
e2m2 along the head_dim axis with one scale per (token, head) (the exact
analogue of channel-wise RTN) and share each mantissa LSB across k=4
neighbors chosen by the paper's adaptive MSE search. Storage per value:

    4-bit hi nibbles (2/int8) + 1 shared LSB per 4 values + f32 scale/head
    = 4.25 bits + 32/head  ->  3.7x smaller cache than bf16.

Each token is quantized ONCE at insert (no repacking of history), so decode
cost is one dequant pass over the cache — on TPU that rides the same
restore-before-MXU pattern as the weight kernel.

This module is the validated numerical core + packed container. It is wired
into decode by the paged KV-cache subsystem (`repro.cache`): page pools store
exactly these planes and the paged-attention kernel restores them on the fly
inside the attention loop — see docs/paged_cache.md for the page layout and
block-table walkthrough.

Head dims that are not a multiple of the sharing group k (or are odd, which
breaks nibble pairing) are zero-padded to the packing width internally;
`dequantize_kv` slices the pad back off. Zero-length and singleton token
axes round-trip too — those are exactly the shapes the paged kernel feeds.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .ams import share_mantissa
from .formats import AMSFormat, code_to_value, get_format, get_scheme
from .rtn import quantize_rtn

KV_SCHEME = get_scheme("fp4.25-e2m2")


def packed_head_dim(hd: int, scheme: AMSFormat = KV_SCHEME) -> int:
    """Padded head dim the packed planes actually store: a multiple of the
    sharing group k AND even (nibble pairing)."""
    return -(-hd // math.lcm(scheme.k, 2)) * math.lcm(scheme.k, 2)


def quantize_kv(x: jnp.ndarray, scheme: AMSFormat = KV_SCHEME,
                strategy: str = "set_lsb"):
    """Quantize [..., hd] vectors -> packed planes.

    Returns dict: hi int8 [..., hd_p/2] (two 4-bit codes per byte),
    lsb int32 [..., ceil(hd_p/k/32)] bitplane (one bit per k-group),
    scale f32 [..., 1] — where hd_p = `packed_head_dim(hd)` (zero-padded when
    hd is odd or not a multiple of k; the pad is sliced off on dequantize).
    """
    fmt = scheme.base
    k = scheme.k
    hd = x.shape[-1]
    hd_p = packed_head_dim(hd, scheme)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, hd).astype(jnp.float32)   # [M, hd]
    if hd_p != hd:
        x2 = jnp.pad(x2, ((0, 0), (0, hd_p - hd)))
    hd = hd_p
    # channel-wise = per-vector scale: treat vectors as columns
    wt = x2.T                                    # [hd, M]
    codes, scale = quantize_rtn(wt, fmt)         # codes [hd, M], scale [M]
    codes = share_mantissa(codes, wt / scale, fmt, k, strategy)
    codes = codes.T                              # [M, hd]

    hi = (codes >> 1).astype(jnp.uint8)          # 4-bit segments
    hi_packed = (hi[:, 0::2] | (hi[:, 1::2] << 4)).astype(jnp.int8)
    g = hd // k                                  # groups per vector
    gw = -(-g // 32)                             # int32 words for the bitplane
    bits = (codes[:, ::k] & 1)                   # [M, g]
    bits = jnp.pad(bits, ((0, 0), (0, gw * 32 - g)))
    shifts = jnp.arange(32, dtype=jnp.int32)[None, None, :]
    lsb = jnp.bitwise_or.reduce(
        (bits.reshape(-1, gw, 32) << shifts), axis=-1).astype(jnp.int32)
    return {
        "hi": hi_packed.reshape(*lead, hd // 2),
        "lsb": lsb.reshape(*lead, gw),
        "scale": scale.reshape(*lead, 1).astype(jnp.float32),
    }


def codes_from_planes(hi: jnp.ndarray, lsb: jnp.ndarray,
                      k: int) -> jnp.ndarray:
    """Packed planes -> full codes [..., hd_p]: split the hi bytes into
    nibbles (position order) and OR the shared LSB back into every group
    member's bit 0. hi: [..., hd_p/2] (raw bytes), lsb: [..., gw] int32.

    Pure SHIFT/AND/OR + reshape ops, so this is THE single definition of
    the plane layout — `dequantize_kv` and the Pallas paged-attention
    kernel (`repro.cache.paged_attention`) both restore through it.
    """
    lead = hi.shape[:-1]
    hd_p = hi.shape[-1] * 2
    byte = hi.astype(jnp.int32) & 0xFF
    codes_hi = jnp.stack([byte & 0xF, (byte >> 4) & 0xF],
                         axis=-1).reshape(*lead, hd_p)
    g = hd_p // k
    gw = lsb.shape[-1]
    bits = jnp.stack([(lsb >> j) & 1 for j in range(32)],
                     axis=-1).reshape(*lead, gw * 32)[..., :g]
    lsb_full = jnp.broadcast_to(bits[..., None],
                                (*lead, g, k)).reshape(*lead, hd_p)
    return (codes_hi << 1) | lsb_full


def dequantize_kv(q, hd: int, scheme: AMSFormat = KV_SCHEME,
                  dtype=jnp.bfloat16) -> jnp.ndarray:
    """Packed planes -> [..., hd] values (bit restore, same as the kernel).

    ``hd`` is the TRUE head dim; the planes store `packed_head_dim(hd)`
    columns and any pad tail is sliced off here.
    """
    fmt = scheme.base
    k = scheme.k
    lead = q["hi"].shape[:-1]
    hd_p = q["hi"].shape[-1] * 2
    codes = codes_from_planes(q["hi"].reshape(-1, hd_p // 2),
                              q["lsb"].reshape(-1, q["lsb"].shape[-1]), k)
    vals = code_to_value(fmt, codes) * q["scale"].reshape(-1, 1)
    return vals.reshape(*lead, hd_p)[..., :hd].astype(dtype)


def kv_bytes(hd: int, scheme: AMSFormat = KV_SCHEME) -> Tuple[int, int]:
    """(packed bytes per vector, bf16 bytes per vector)."""
    hd_p = packed_head_dim(hd, scheme)
    g = hd_p // scheme.k
    gw = -(-g // 32)
    return hd_p // 2 + 4 * gw + 4, 2 * hd
