"""Stable serving facade — the supported import surface for users.

    from repro.serving import EngineConfig, ServeEngine, SamplingParams

    eng = ServeEngine(EngineConfig(cache=CacheConfig(kind="paged_ams")))
    handle = eng.submit(prompt_ids, max_tokens=64, priority=1)
    tokens = handle.result()            # or: async for t in handle.stream()

Everything re-exported here is covered by the API tests
(tests/test_engine_api.py); internals under ``repro.launch.*`` and
``repro.cache.*`` may move between releases, these names will not.
"""

from __future__ import annotations

from repro.cache.config import CacheConfig
from repro.launch.config import EngineConfig
from repro.launch.engine import RequestHandle, ServeEngine
from repro.launch.frontend import ServeFrontend, serve
from repro.launch.sampling import SamplingParams
from repro.launch.scheduler import Request, SpilledState
from repro.obs import ObsConfig

__all__ = [
    "CacheConfig",
    "EngineConfig",
    "ObsConfig",
    "Request",
    "RequestHandle",
    "SamplingParams",
    "ServeEngine",
    "ServeFrontend",
    "SpilledState",
    "serve",
]
