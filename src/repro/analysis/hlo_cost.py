"""Trip-count-aware cost model over optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts every while-loop body ONCE — our
steps scan over layer groups (and microbatches), so its numbers are per-
iteration, not per-step. This module parses the HLO module text into its
computation regions, recovers each while loop's trip count from its
condition region (lax.scan lowers to `compare(iv, constant(N)), direction=LT`
— verified by test), and accumulates:

  * flops               — dots (2*M*N*K from operand shapes + contracting
                          dims), convolutions, and elementwise ops (1 flop /
                          output element), multiplied through loop nests;
  * hbm_bytes           — an HBM-traffic model: for every top-level fusion /
                          dot / copy / collective, operands + outputs
                          (fusion-internal temporaries stay in registers /
                          don't round-trip HBM);
  * collective_bytes    — per kind, trip-multiplied.

All values are per-device (the HLO module is one SPMD partition).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\((.*)$")
_CALLED_ONE = re.compile(r"(?:calls|body|condition|to_apply)=%([\w\.\-]+)")
_CALLED_SET = re.compile(r"(?:calls|branch_computations)=\{([^}]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")

COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "all-gather-start", "all-reduce-start",
               "reduce-scatter-start", "collective-permute-start"}

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "floor", "ceil", "round-nearest-afz", "select", "compare", "and", "or",
    "xor", "not", "clamp", "convert", "sine", "cosine", "logistic",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "exponential-minus-one", "log-plus-one", "cbrt", "erf", "remainder",
}


def _parse_shapes(s: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(s):
        if dt in _DTYPE_BYTES:
            shape = tuple(int(d) for d in dims.split(",")) if dims else ()
            out.append((dt, shape))
    return out


def _nbytes(shapes) -> int:
    tot = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        tot += n * _DTYPE_BYTES[dt]
    return tot


def _nelems(shapes) -> int:
    tot = 0
    for _, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        tot += n
    return tot


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    out_shapes: list
    operands: List[str]
    called: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, Op]
    order: List[str]
    root: Optional[str] = None


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        hdr = _COMP_HDR.match(s)
        if hdr and s.endswith("{") and ") -> " in s and "=" not in s.split("(")[0]:
            cur = Computation(hdr.group(1), {}, [])
            comps[cur.name] = cur
            if s.startswith("ENTRY"):
                entry = cur.name
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, outtype, kind, rest = m.groups()
        out_shapes = _parse_shapes(outtype)
        # operands: %refs before the closing paren of the op call
        arg_str = rest.split(")")[0]
        operands = re.findall(r"%([\w\.\-]+)", arg_str)
        called = []
        for cm in _CALLED_ONE.finditer(rest):
            called.append(cm.group(1))
        for cm in _CALLED_SET.finditer(rest):
            called.extend(x.strip().lstrip("%")
                          for x in cm.group(1).split(",") if x.strip())
        cur.ops[name] = Op(name, kind, out_shapes, operands, called, rest)
        cur.order.append(name)
        if s.startswith("ROOT"):
            cur.root = name
    return comps, entry


_SLICING = {"dynamic-slice", "gather", "slice"}


def _fusion_operand_bytes(op: Op, idx: int, oname: str, sym, comps) -> float:
    """HBM bytes read for one fusion operand.

    A fusion that dynamic-slices a big buffer (the scan-over-layers stacked
    weight pattern) only reads the slice, not the whole buffer — charge the
    consumers' output size instead of the full operand in that case."""
    full = _nbytes(sym.get(oname, []))
    inner = comps.get(op.called[0]) if op.called else None
    if inner is None:
        return full
    # find parameter(idx) in the fused computation
    pname = None
    for n in inner.order:
        o = inner.ops[n]
        if o.kind == "parameter" and o.attrs.strip().startswith(f"{idx})"):
            pname = n
            break
    if pname is None:
        return full
    consumers = [inner.ops[n] for n in inner.order
                 if pname in inner.ops[n].operands]
    if not consumers:
        return full
    reads = 0.0
    for c in consumers:
        if c.kind in _SLICING:
            reads += _nbytes(c.out_shapes)
        elif c.kind in ("fusion", "call") and c.called:
            # some backends wrap the slice fusion in another call/fusion
            # layer (e.g. CPU's parallel_* call wrappers) — recurse at
            # EVERY operand position this buffer feeds (it may appear
            # more than once), with the consumer's own index each time
            sub_sym = {pname: inner.ops[pname].out_shapes}
            for j, on in enumerate(c.operands):
                if on != pname:
                    continue
                r = _fusion_operand_bytes(c, j, pname, sub_sym, comps)
                if r >= full:
                    return full
                reads += r
        else:
            return full
    return min(full, reads)


def _inplace_update_bytes(op: Op, comps) -> Optional[Tuple[float, float]]:
    """If the fusion contains dynamic-update-slice(param, update, ...) on a
    buffer parameter (the scan-ys / KV-cache write pattern — possibly wrapped
    in dtype converts by the CPU backend), return (update_bytes,
    update_elems); else None. Such fusions touch only the updated slice in
    HBM per iteration, whatever XLA's convert games say."""
    inner = comps.get(op.called[0]) if op.called else None
    if inner is None:
        return None
    for n in inner.order:
        o = inner.ops[n]
        if o.kind != "dynamic-update-slice" or len(o.operands) < 2:
            continue
        tgt = inner.ops.get(o.operands[0])
        upd = inner.ops.get(o.operands[1])
        if upd is None or tgt is None:
            continue
        # target must trace back to a parameter (possibly via convert/bitcast)
        seen = 0
        while tgt is not None and tgt.kind in ("convert", "bitcast", "copy") and seen < 4:
            tgt = inner.ops.get(tgt.operands[0]) if tgt.operands else None
            seen += 1
        if tgt is not None and tgt.kind == "parameter":
            return float(_nbytes(upd.out_shapes)), float(_nelems(upd.out_shapes))
    return None


def _dot_flops(op: Op, sym: Dict[str, list]) -> float:
    lhs = sym.get(op.operands[0]) if op.operands else None
    rhs = sym.get(op.operands[1]) if len(op.operands) > 1 else None
    if not lhs or not rhs:
        return 0.0
    lhs_dims = lhs[0][1]
    rhs_dims = rhs[0][1]
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    mb = re.search(r"lhs_batch_dims=\{([0-9,]*)\}", op.attrs)
    contract = [int(x) for x in mc.group(1).split(",")] if mc and mc.group(1) else []
    batch = [int(x) for x in mb.group(1).split(",")] if mb and mb.group(1) else []
    k = 1
    for d in contract:
        k *= lhs_dims[d] if d < len(lhs_dims) else 1
    out_elems = _nelems(op.out_shapes)
    return 2.0 * out_elems * k


def is_condition(comp: Computation) -> bool:
    """Scan/while condition regions root in EXACTLY one scalar pred."""
    root_name = comp.root or (comp.order[-1] if comp.order else None)
    if root_name is None:
        return False
    root = comp.ops[root_name]
    return root.out_shapes == [("pred", ())]


def trip_count(cond: Computation) -> int:
    """lax.scan condition is `iv < N`; N is the only (max) integer constant
    in the region (possibly feeding a compare wrapped in a fusion)."""
    best = 1
    for name in cond.order:
        op = cond.ops[name]
        if op.kind == "constant":
            m = re.match(r"\s*(\d+)\)", op.attrs)
            if m:
                best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))      # raw output bytes
    traffic: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))      # ring-model link bytes

    def scaled(self, k: float) -> "Cost":
        c = Cost(self.flops * k, self.hbm_bytes * k)
        for kk, v in self.collectives.items():
            c.collectives[kk] = v * k
        for kk, v in self.traffic.items():
            c.traffic[kk] = v * k
        return c

    def add(self, o: "Cost"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        for k, v in o.collectives.items():
            self.collectives[k] += v
        for k, v in o.traffic.items():
            self.traffic[k] += v


def _group_size(attrs: str) -> int:
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    return 2


def _ring_traffic(kind: str, out_bytes: float, g: int) -> float:
    """Per-device link bytes under a ring model, from op OUTPUT bytes."""
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return out_bytes * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * out_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return out_bytes * (g - 1)          # output is the shard
    if kind == "all-to-all":
        return out_bytes * (g - 1) / g
    if kind == "collective-permute":
        return out_bytes
    return out_bytes


# constant extraction needs the raw line; patch: store full line in attrs.
def _flops_only(comp: Computation, comps, memo) -> Cost:
    return _cost_of(comp, comps, memo, top_level=False)


def _cost_of(comp: Computation, comps: Dict[str, Computation], memo,
             top_level: bool) -> Cost:
    key = (comp.name, top_level)
    if key in memo:
        return memo[key]
    total = Cost()
    sym = {name: comp.ops[name].out_shapes for name in comp.order}
    for name in comp.order:
        op = comp.ops[name]
        k = op.kind
        if k == "while":
            tc = 1
            body_c = Cost()
            cands = [comps[c] for c in op.called if c in comps]
            conds = [c for c in cands if is_condition(c)]
            bodies = [c for c in cands if not is_condition(c)]
            if conds:
                tc = max(trip_count(c) for c in conds)
            for b in bodies:
                body_c.add(_cost_of(b, comps, memo, top_level=True))
            total.add(body_c.scaled(tc))
        elif k in ("fusion", "call", "custom-call", "map", "reduce-window",
                   "conditional", "sort", "scatter"):
            inner = Cost()
            for cn in op.called:
                if cn in comps:
                    inner.add(_cost_of(comps[cn], comps, memo,
                                       top_level=False))
            upd = _inplace_update_bytes(op, comps)
            if upd is not None:
                # in-place cache/ys write: only the updated slice matters
                # (XLA's full-buffer convert wrappers are buffer-dtype
                # bookkeeping, not streamed math)
                total.flops += min(inner.flops, 4 * upd[1])
            else:
                total.flops += inner.flops
            for kk, v in inner.collectives.items():
                total.collectives[kk] += v
            if top_level:
                opnd_bytes = sum(
                    _fusion_operand_bytes(op, i, o, sym, comps)
                    for i, o in enumerate(op.operands))
                out_bytes = _nbytes(op.out_shapes)
                if upd is not None:
                    out_bytes = upd[0]
                    opnd_bytes = min(opnd_bytes, upd[0])
                total.hbm_bytes += opnd_bytes + out_bytes
        elif k == "dot":
            total.flops += _dot_flops(op, sym)
            if top_level:
                opnd_bytes = sum(_nbytes(sym.get(o, [])) for o in op.operands)
                total.hbm_bytes += opnd_bytes + _nbytes(op.out_shapes)
        elif k == "convolution":
            # rough: 2 * out_elems * (kernel elems); kernel = operand 1
            kb = sym.get(op.operands[1], []) if len(op.operands) > 1 else []
            total.flops += 2.0 * _nelems(op.out_shapes) * max(1, _nelems(kb))
            if top_level:
                total.hbm_bytes += sum(_nbytes(sym.get(o, []))
                                       for o in op.operands) + _nbytes(op.out_shapes)
        elif k in COLLECTIVES:
            kind = k.replace("-start", "")
            b = _nbytes(op.out_shapes)
            g = _group_size(op.attrs)
            total.collectives[kind] += b
            total.collectives["total"] += b
            t = _ring_traffic(kind, b, g)
            total.traffic[kind] += t
            total.traffic["total"] += t
            if top_level:
                total.hbm_bytes += b
        elif k in ELEMENTWISE or k in ("reduce", "broadcast", "iota",
                                       "transpose", "reshape", "concatenate",
                                       "slice", "dynamic-slice",
                                       "dynamic-update-slice", "pad", "gather",
                                       "reverse", "rng", "copy"):
            if k in ELEMENTWISE or k == "reduce":
                total.flops += _nelems(op.out_shapes)
            if top_level and k in _SLICING:
                total.hbm_bytes += 2 * _nbytes(op.out_shapes)
            elif top_level and k == "dynamic-update-slice":
                upd = (_nbytes(sym.get(op.operands[1], []))
                       if len(op.operands) > 1 else _nbytes(op.out_shapes))
                total.hbm_bytes += 2 * upd
            elif top_level and k in ("copy", "transpose", "reshape",
                                     "concatenate", "broadcast", "pad",
                                     "reduce"):
                opnd_bytes = sum(_nbytes(sym.get(o, [])) for o in op.operands)
                total.hbm_bytes += opnd_bytes + _nbytes(op.out_shapes)
            elif top_level and k in ELEMENTWISE:
                opnd_bytes = sum(_nbytes(sym.get(o, [])) for o in op.operands)
                total.hbm_bytes += opnd_bytes + _nbytes(op.out_shapes)
    memo[key] = total
    return total


def module_cost(hlo_text: str) -> Cost:
    comps, entry = parse_module(hlo_text)
    if entry is None:
        # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c].order)) if comps else None
    memo: dict = {}
    if entry is None:
        return Cost()
    return _cost_of(comps[entry], comps, memo, top_level=True)
