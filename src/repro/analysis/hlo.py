"""Post-SPMD HLO text analysis: collective operand bytes + op histograms.

cost_analysis() has FLOPs/bytes but NOT collective traffic; we parse the
optimized HLO module text (one SPMD partition) and sum the *output* shape
bytes of every collective op, bucketed by kind. Sizes are therefore
per-device, matching cost_analysis granularity.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
)

# e.g.  %all-gather.3 = bf16[4,2048]{1,0} all-gather(%param.1), ...
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+([a-z0-9\-]+)\(")
_TUPLE_RE = re.compile(
    r"=\s*\(\s*(.*?)\)\s+([a-z0-9\-]+)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output bytes of each collective kind in the optimized HLO."""
    out: Dict[str, float] = defaultdict(float)
    for line in hlo_text.splitlines():
        line = line.strip()
        stripped = line.lstrip("% ")
        # find op kind by looking for " <kind>(" with a known collective
        m = _OP_RE.search(line)
        kind = None
        size = 0
        if m and m.group(3) in COLLECTIVE_KINDS:
            kind = m.group(3)
            size = _shape_bytes(m.group(1), m.group(2))
        else:
            mt = _TUPLE_RE.search(line)
            if mt and mt.group(2) in COLLECTIVE_KINDS:
                kind = mt.group(2)
                size = sum(_shape_bytes(d, s)
                           for d, s in _SHAPE_RE.findall(mt.group(1)))
        if kind:
            kind = kind.replace("-start", "")
            out[kind] += size
            out["total"] += size
    return dict(out)


def hlo_op_histogram(hlo_text: str, top: int = 25) -> Dict[str, int]:
    """Count op kinds (fusion/dot/collective/...) — remat & redundancy hints."""
    hist: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m:
            hist[m.group(3)] += 1
        else:
            mt = _TUPLE_RE.search(line)
            if mt:
                hist[mt.group(2)] += 1
    items = sorted(hist.items(), key=lambda kv: -kv[1])[:top]
    return dict(items)
