"""Roofline analysis over dry-run records (§Roofline deliverable).

Per (arch x shape x mesh) cell, from the trip-count-aware parsed HLO costs
(analysis/hlo_cost.py — XLA's cost_analysis counts loop bodies once, ours
multiplies through the loop nest):

    compute term    = parsed_flops   / PEAK_FLOPS          (s)
    memory term     = parsed_hbm     / HBM_BW              (s)
    collective term = parsed_traffic / (LINKS * LINK_BW)   (s)

plus MODEL_FLOPS = 6*N*D (train) / 2*N_active*D (inference) per device and
the usefulness ratio MODEL_FLOPS / parsed_flops.

Hardware: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(we model 3 usable link-pairs per chip on a 2D torus slice -> the collective
term uses 1 link of 50 GB/s as the conservative per-device serialization).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


# --------------------------------------------------------------------------
# Analytic parameter counts / MODEL_FLOPS
# --------------------------------------------------------------------------
def param_count(cfg) -> Dict[str, float]:
    """(total, active) parameter counts of the true (unpadded) architecture."""
    D, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    emb = V * D
    head = D * V
    per_layer = 0.0
    per_layer_active = 0.0

    def attn_params():
        if cfg.attention == "mla":
            H = cfg.num_heads
            p = (D * cfg.q_lora_rank
                 + cfg.q_lora_rank * H * (cfg.qk_nope_dim + cfg.qk_rope_dim)
                 + D * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                 + cfg.kv_lora_rank * H * cfg.qk_nope_dim
                 + cfg.kv_lora_rank * H * cfg.v_head_dim
                 + H * cfg.v_head_dim * D)
            return p
        if cfg.attention == "none":
            return 0
        hd = cfg.head_dim
        return (D * cfg.num_heads * hd + 2 * D * cfg.num_kv_heads * hd
                + cfg.num_heads * hd * D)

    def ffn_params(width):
        mult = 3 if cfg.ffn_activation.endswith("_glu") else 2
        return mult * D * width

    if cfg.family == "ssm":
        di, n = cfg.d_inner, cfg.ssm_state
        dtr = cfg.dt_rank or D // 16
        per_layer = (D * 2 * di + cfg.ssm_conv * di + di * (dtr + 2 * n)
                     + dtr * di + di * n + di + di * D)
        per_layer_active = per_layer
        total = emb + head + L * per_layer
        active = total
        return {"total": total, "active": active}

    if cfg.family == "hybrid":
        W = cfg.lru_width
        rec = D * 2 * W + 4 * W + 2 * W * W + W * D + ffn_params(cfg.d_ff)
        att = attn_params() + ffn_params(cfg.d_ff)
        pat = cfg.block_pattern
        counts = {"rec": rec, "attn": att}
        tot = sum(counts[k] for k in
                  [pat[i % len(pat)] for i in range(L)])
        total = emb + head + tot
        return {"total": total, "active": total}

    att = attn_params()
    if cfg.num_experts:
        experts = cfg.num_experts * ffn_params(cfg.d_ff)
        shared = ffn_params(cfg.moe_shared_expert_ff) if cfg.moe_shared_expert_ff else 0
        router = D * cfg.num_experts
        per_layer = att + experts + shared + router
        per_layer_active = (att + cfg.experts_per_token * ffn_params(cfg.d_ff)
                            + shared + router)
    else:
        per_layer = att + ffn_params(cfg.d_ff)
        per_layer_active = per_layer
    total = emb + head + L * per_layer
    active = emb + head + L * per_layer_active
    return {"total": total, "active": active}


def model_flops_per_device(cfg, shape_mode: str, seq: int, batch: int,
                           devices: int) -> float:
    """Text-book MODEL_FLOPS (6ND train / 2ND forward), per device."""
    pc = param_count(cfg)
    N = pc["active"]
    if shape_mode == "train":
        tokens = seq * batch
        return 6.0 * N * tokens / devices
    if shape_mode == "prefill":
        tokens = seq * batch
        return 2.0 * N * tokens / devices
    # decode: one token per sequence + attention over the cache
    tokens = batch
    return 2.0 * N * tokens / devices


# --------------------------------------------------------------------------
# Roofline terms
# --------------------------------------------------------------------------
def weight_bytes_per_device(cfg, quant: str, devices: int, mode: str) -> float:
    """Per-device weight bytes: packed bits for quantized serving, bf16 for
    train (sharded over the whole mesh via TP x FSDP for train, TP for serve)."""
    pc = param_count(cfg)
    if quant not in ("bf16", "fp16") and mode != "train":
        from repro.core.formats import SCHEMES
        bits = SCHEMES[quant].effective_bits if quant in SCHEMES else 16
        tp = 16  # serve shards weights over the model axis only
        return pc["total"] * bits / 8 / tp
    share = devices if mode == "train" else 16
    return pc["total"] * 2.0 / share


def cache_bytes_per_device(cfg, seq: int, batch: int, devices: int) -> float:
    """Decode KV/state cache bytes per device (bf16)."""
    B_loc = max(1, batch // min(16, batch))  # batch over data axis
    dims_kv = cfg.num_kv_heads * cfg.head_dim
    if cfg.attention == "mla":
        dims_kv = cfg.kv_lora_rank + cfg.qk_rope_dim
    if cfg.family == "ssm":
        return cfg.num_layers * B_loc * cfg.d_inner * (cfg.ssm_state + cfg.ssm_conv) * 4 / 16
    S_eff = seq / 16  # sequence-sharded over model axis
    if cfg.sliding_window:
        n_attn = sum(1 for i in range(cfg.num_layers)
                     if cfg.block_pattern and cfg.block_pattern[i % len(cfg.block_pattern)] == "attn")
        return n_attn * B_loc * min(cfg.sliding_window, seq) / 16 * dims_kv * 2 * 2
    mult = 1 if cfg.attention == "mla" else 2  # MLA: one compressed stream
    return cfg.num_layers * B_loc * S_eff * dims_kv * 2 * mult


def analytic_memory_floor(cfg, quant: str, mode: str, seq: int, batch: int,
                          devices: int) -> float:
    """Lower-bound HBM traffic/step/device on the TPU target: every weight
    byte once (packed), the decode cache once, plus O(activations)."""
    w = weight_bytes_per_device(cfg, quant, devices, mode)
    # activation flow: ~4 full-width tensors r/w per layer per token
    act = batch * seq / devices * cfg.d_model * 2 * 4 * max(1, cfg.num_layers)
    if mode == "train":
        return 3 * w + 3 * act  # fwd+bwd+remat weight reads, act r/w
    if mode == "prefill":
        return w + act
    return w + cache_bytes_per_device(cfg, seq, batch, devices) + batch / devices * cfg.d_model * 2


def analyze(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    from repro.launch.specs import SHAPES
    seq, batch, mode = SHAPES[rec["shape"]]
    devices = rec["devices"]
    flops = rec.get("parsed_flops", 0.0)
    hbm = rec.get("parsed_hbm_bytes", 0.0)
    traffic = rec.get("parsed_traffic", {}).get("total", 0.0)

    t_comp = flops / PEAK_FLOPS
    t_mem = hbm / HBM_BW
    t_coll = traffic / LINK_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(cfg, mode, seq, batch, devices)
    bound = max(terms.values())
    ideal = mf / PEAK_FLOPS
    # analytic TPU-target floor: the CPU-compiled artifact inserts dtype
    # converts/copies a TPU compiler fuses away; this is the memory term the
    # same program lower-bounds to on the target (packed weights + cache).
    floor_b = analytic_memory_floor(cfg, rec.get("quant", "bf16"), mode, seq,
                                    batch, devices)
    t_mem_floor = floor_b / HBM_BW
    bound_floor = max(t_comp, t_mem_floor, t_coll)
    return {
        **{k: round(v, 6) for k, v in terms.items()},
        "memory_floor_s": round(t_mem_floor, 6),
        "dominant": dominant.replace("_s", ""),
        "model_flops_per_device": mf,
        "useful_flops_ratio": round(mf / flops, 4) if flops else None,
        "roofline_fraction": round(ideal / bound, 4) if bound else None,
        "roofline_fraction_target": round(ideal / bound_floor, 4) if bound_floor else None,
        "step_time_bound_s": round(bound, 6),
    }


def load_records(out_dir: str, mesh: str = "pod256",
                 tag: Optional[str] = None) -> List[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, mesh, "*.json"))):
        base = os.path.basename(p)[:-5]
        has_tag = "__" in base.split("__", 2)[-1] if base.count("__") >= 2 else False
        if tag is None and base.count("__") >= 2:
            continue
        if tag is not None and not base.endswith(f"__{tag}"):
            continue
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def table(recs: List[dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'dom':10s} "
           f"{'compute(s)':>11s} {'memory(s)':>11s} {'mem_floor':>10s} "
           f"{'collect(s)':>11s} {'useful':>7s} {'roofl%':>7s} "
           f"{'tgt%':>6s} {'peakGiB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        a = analyze(r)
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {a['dominant']:10s} "
            f"{a['compute_s']:11.4g} {a['memory_s']:11.4g} "
            f"{a['memory_floor_s']:10.4g} {a['collective_s']:11.4g} "
            f"{(a['useful_flops_ratio'] or 0):7.3f} "
            f"{100*(a['roofline_fraction'] or 0):7.2f} "
            f"{100*(a['roofline_fraction_target'] or 0):6.1f} "
            f"{r['memory']['peak_bytes']/2**30:8.2f}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod256")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    recs = load_records(args.dir, args.mesh, args.tag)
    print(table(recs))
    if args.json_out:
        out = [{**{k: r[k] for k in ("arch", "shape", "mesh", "quant")},
                **analyze(r)} for r in recs]
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
