"""LR schedules: linear warmup + cosine decay (the usual LLM recipe)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = base_lr * jnp.minimum(1.0, (step + 1) / max(1, warmup))
    prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, base_lr * cos)
