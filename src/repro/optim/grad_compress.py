"""Gradient compression for the data-parallel all-reduce.

A ring all-reduce is reduce_scatter + all_gather. The reduce_scatter half
must stay high precision (it sums partial gradients), but the all_gather
half broadcasts an already-reduced value — it can be int8-quantized with a
per-shard scale for a ~4x byte reduction of that half (visible as smaller
all-gather operands in the dry-run HLO):

    g -> psum_scatter(f32) -> quantize int8 -> all_gather -> dequantize

Error analysis: quantization happens after the sum, so no error accumulates
across workers; worst case is 1/2 ulp of the int8 grid, |g_shard|_max / 254.

``compressed_psum`` is used INSIDE a manual-axes (shard_map) region — the
cross-pod gradient reduction in train_step. ``compressed_allreduce`` wraps
it in its own shard_map for standalone use/tests.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _axes_size(axes) -> None:
    pass  # world size is resolved by the collectives themselves


def compressed_psum(grads, axes: Sequence[str]):
    """psum a grad pytree over manual mesh axes with int8 all-gather half.

    Must run inside a shard_map over (at least) `axes`. Small leaves that
    don't tile evenly fall back to plain psum.
    """
    axes = tuple(axes)

    def world():
        n = 1
        for a in axes:
            # jax.lax.axis_size is missing on 0.4.x; psum(1, axis) is the
            # portable spelling (constant-folded under manual axes)
            n *= (jax.lax.axis_size(a) if hasattr(jax.lax, "axis_size")
                  else jax.lax.psum(1, a))
        return n

    w = world()

    def one(g):
        gf = g.astype(jnp.float32)
        flat = gf.reshape(-1)
        if flat.shape[0] % w != 0 or flat.shape[0] < 8 * w:
            return jax.lax.psum(gf, axes)
        red = jax.lax.psum_scatter(flat, axes, scatter_dimension=0, tiled=True)
        amax = jnp.max(jnp.abs(red))
        scale = jnp.maximum(amax, 1e-30) / 127.0
        q = jnp.clip(jnp.round(red / scale), -127, 127).astype(jnp.int8)
        qg = jax.lax.all_gather(q, axes, axis=0, tiled=True)
        sg = jax.lax.all_gather(scale[None], axes, axis=0)
        shard = red.shape[0]
        out = (qg.reshape(w, shard) * sg.reshape(w, 1)).reshape(flat.shape)
        return out.reshape(g.shape)

    return jax.tree.map(one, grads)


def compressed_allreduce(grads, mesh, dp_axes: Sequence[str]):
    """Standalone wrapper: all-reduce replicated-view grads over dp_axes."""
    from repro.launch.mesh import compat_shard_map
    specs = jax.tree.map(lambda _: P(), grads)
    f = compat_shard_map(lambda g: compressed_psum(g, dp_axes), mesh,
                         set(dp_axes), in_specs=(specs,), out_specs=specs)
    return f(grads)
