"""AdamW with decoupled weight decay + global-norm clipping (from scratch).

State layout mirrors the param pytree: {m, v} in f32 plus an i32 step.
Master params are f32; the training loop computes grads in bf16 compute /
f32 accumulate and applies updates to the f32 masters (mixed-precision
recipe). With FSDP, m/v inherit the parameter sharding, i.e. optimizer
state is sharded over (data x model) — ZeRO-ish for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def _decayable(path) -> bool:
    """Decay 2D+ matrices; skip norms/biases/scalars (standard practice)."""
    last = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    return last == "w"


def apply_updates(params, grads, state, lr, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(path, p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decayable(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree.structure(params)
    g_leaves = jax.tree.leaves(grads)
    m_leaves = jax.tree.leaves(state["m"])
    v_leaves = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(flat, g_leaves, m_leaves, v_leaves):
        np_, nm, nv = upd(path, p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    params = jax.tree.unflatten(treedef, new_p)
    new_state = {"m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v),
                 "step": step}
    return params, new_state, {"grad_norm": gn}
