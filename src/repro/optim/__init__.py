from .adamw import (  # noqa: F401
    AdamWConfig,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    init_state,
)
from .grad_compress import compressed_allreduce, compressed_psum  # noqa: F401
from .schedule import warmup_cosine  # noqa: F401
