"""Fault-tolerant checkpointing: sharded npz, atomic, async, resumable.

Layout:  <dir>/step_<n>/shard_<i>.npz + MANIFEST.json (written LAST — a
checkpoint without a manifest is incomplete and ignored on restore, which
makes the save atomic under crash-at-any-point). A background writer thread
overlaps serialization with the next training steps; ``wait()`` drains it.

Restore picks the newest *complete* step, so a node failure mid-save falls
back to the previous checkpoint (crash-consistency test covers this).
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        items.append((key, leaf))
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str, max_to_keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.max_to_keep = max_to_keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._thread = None
        if async_save:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, blocking: bool = False,
             shard_id: int = 0, num_shards: int = 1):
        """Snapshot to host memory now; write in the background."""
        items, _ = _flatten(tree)
        host = {k: np.asarray(v) for k, v in items}  # device -> host copy
        job = (step, host, shard_id, num_shards)
        if self._thread is None or blocking:
            self._write(job)
        else:
            self._q.put(job)

    def _worker(self):
        while True:
            job = self._q.get()
            if job is None:
                self._q.task_done()
                return
            try:
                self._write(job)
            except BaseException as e:  # surfaced on wait()
                self._err = e
            finally:
                self._q.task_done()

    def _write(self, job):
        step, host, shard_id, num_shards = job
        d = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(d, exist_ok=True)
        # unique tmp name: a blocking save may race an async save of the
        # same step (both are atomic via os.replace, last one wins)
        tmp = os.path.join(
            d, f".tmp_shard_{shard_id}_{os.getpid()}_{time.monotonic_ns()}.npz")
        np.savez(tmp, **host)
        os.replace(tmp, os.path.join(d, f"shard_{shard_id}.npz"))
        # manifest written last == commit point
        if shard_id == num_shards - 1:
            man = {"step": step, "num_shards": num_shards,
                   "time": time.time(),
                   "keys": sorted(host.keys())}
            mtmp = os.path.join(d, ".tmp_manifest")
            with open(mtmp, "w") as f:
                json.dump(man, f)
            os.replace(mtmp, os.path.join(d, "MANIFEST.json"))
            self._gc()

    def _gc(self):
        steps = self.complete_steps()
        for s in steps[: -self.max_to_keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        """Drain pending async saves; re-raise background errors."""
        if self._thread is not None:
            self._q.join()
        if self._err:
            err, self._err = self._err, None
            raise err

    # ---------------------------------------------------------- restore
    def complete_steps(self) -> List[int]:
        out = []
        for name in sorted(os.listdir(self.dir)):
            if not name.startswith("step_"):
                continue
            if os.path.exists(os.path.join(self.dir, name, "MANIFEST.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.complete_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: Optional[int] = None,
                shard_id: int = 0):
        """Restore into the structure of `tree_like` (shapes validated)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        d = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(d, f"shard_{shard_id}.npz"))
        items, treedef = _flatten(tree_like)
        leaves = []
        for key, like in items:
            arr = data[key]
            if tuple(arr.shape) != tuple(np.shape(like)):
                raise ValueError(
                    f"checkpoint shape mismatch at {key}: "
                    f"{arr.shape} vs {np.shape(like)}")
            leaves.append(arr)
        return jax.tree.unflatten(treedef, leaves), step
