"""Per-architecture smoke tests on REDUCED configs (CPU, 1 device).

For each assigned arch: instantiate a tiny same-family config, run one
forward/train step and a prefill->decode chain; assert shapes + finiteness,
and that decode logits match the prefill forward at the same position
(cache-consistency — the strongest cheap correctness check we have).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import decode_step, forward_seq, init_params, make_cache

ARCHS = list_archs()


def tiny(name):
    return get_config(name).reduced()


def data(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    prefix = None
    if cfg.num_prefix_embeds:
        prefix = jnp.asarray(
            rng.standard_normal((B, cfg.num_prefix_embeds, cfg.d_model)),
            jnp.float32)
    return tokens, prefix


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = tiny(arch)
    tokens, prefix = data(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    logits, aux, _ = forward_seq(params, tokens, cfg, prefix_embeds=prefix,
                                 dtype=jnp.float32, remat=False)
    S_total = tokens.shape[1] + (prefix.shape[1] if prefix is not None else 0)
    from repro.models import model_dims
    V = model_dims(cfg, 1).V
    assert logits.shape == (2, S_total, V)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads_finite(arch):
    cfg = tiny(arch)
    tokens, prefix = data(cfg)
    params = init_params(jax.random.PRNGKey(1), cfg)

    def loss_fn(p):
        logits, aux, _ = forward_seq(p, tokens[:, :-1], cfg,
                                     prefix_embeds=prefix,
                                     dtype=jnp.float32, remat=True)
        tgt = tokens[:, 1:]
        pl = logits[:, -tgt.shape[1]:]  # skip prefix positions
        ll = jax.nn.log_softmax(pl, axis=-1)
        nll = -jnp.take_along_axis(ll, tgt[..., None], axis=-1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    # embedding grad must be nonzero (learning signal flows end to end)
    assert float(jnp.abs(grads["embed"]["w"]).max()) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode(t | cache(prefill(t_0..t_{n-1}))) == forward(t_0..t_n)[-1]."""
    cfg = tiny(arch)
    B, S = 2, 12
    tokens, prefix = data(cfg, B=B, S=S, seed=3)
    params = init_params(jax.random.PRNGKey(2), cfg)
    P = prefix.shape[1] if prefix is not None else 0

    # full forward over S tokens
    full_logits, _, _ = forward_seq(params, tokens, cfg, prefix_embeds=prefix,
                                    dtype=jnp.float32, remat=False)

    # prefill on S-1 tokens, then decode token S-1
    pre_logits, _, cache = forward_seq(params, tokens[:, :-1], cfg,
                                       prefix_embeds=prefix, want_cache=True,
                                       dtype=jnp.float32, remat=False)
    # prefill caches have capacity P+S-1; decode inserts at pos P+S-1 -> need
    # capacity P+S: re-host into a larger zero cache
    cap = P + S
    big = make_cache(cfg, B, cap, dtype=jnp.float32)

    def embed_into(big_leaf, small_leaf):
        if big_leaf.shape == small_leaf.shape:
            return small_leaf.astype(big_leaf.dtype)
        # sequence-capacity axis is axis 2 for stacked [G, B, S, ...] leaves
        # and axis 1 for unstacked; pad at the end
        pads = [(0, b - s) for b, s in zip(big_leaf.shape, small_leaf.shape)]
        return jnp.pad(small_leaf.astype(big_leaf.dtype), pads)

    cache = jax.tree.map(embed_into, big, cache)
    dec_logits, _ = decode_step(params, tokens[:, -1], cache,
                                jnp.int32(P + S - 1), cfg, dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits[:, -1]),
        rtol=2e-4, atol=2e-4)


def test_vocab_padding_masked():
    cfg = tiny("qwen2-7b")
    tokens, _ = data(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    from repro.models import model_dims
    # simulate tp=4 padding: vocab 512 is already divisible; force odd vocab
    import dataclasses
    cfg2 = dataclasses.replace(cfg, vocab_size=509)
    params2 = init_params(jax.random.PRNGKey(0), cfg2, tp=4)
    tokens2 = jnp.clip(tokens, 0, 508)
    logits, _, _ = forward_seq(params2, tokens2, cfg2, tp=4,
                               dtype=jnp.float32, remat=False)
    V = model_dims(cfg2, 4).V
    assert V == 512
    probs = jax.nn.softmax(logits, axis=-1)
    pad_mass = float(probs[..., 509:].sum())
    assert pad_mass < 1e-6


def test_head_padding_dead():
    """Padded q-heads must not influence the output."""
    cfg = tiny("qwen1.5-4b")  # 4 heads reduced; pad to tp=8
    tokens, _ = data(cfg)
    p8 = init_params(jax.random.PRNGKey(5), cfg, tp=8)
    logits, _, _ = forward_seq(p8, tokens, cfg, tp=8, dtype=jnp.float32,
                               remat=False)
    # zero out padded-head columns of wq: output must be identical
    # (padded slots are group-major interleaved — use head_mask)
    from repro.models import model_dims
    dims = model_dims(cfg, 8)
    hd = dims.hd
    col_mask = np.repeat(np.asarray(dims.head_mask), hd)  # [H*hd]

    p8b = jax.tree.map(lambda x: x, p8)
    w = p8b["layers"]["sub0"]["attn"]["wq"]["w"]
    p8b["layers"]["sub0"]["attn"]["wq"]["w"] = w * jnp.asarray(col_mask)[None, None, :]
    logits2, _, _ = forward_seq(p8b, tokens, cfg, tp=8, dtype=jnp.float32,
                                remat=False)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2),
                               rtol=1e-6, atol=1e-6)
