"""Tests: optimizer, schedule, data pipeline, checkpoint manager."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLM
from repro.optim import (
    AdamWConfig,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    init_state,
    warmup_cosine,
)


# ------------------------------------------------------------------ optim
def test_adamw_reduces_quadratic_loss():
    params = {"lin": {"w": jnp.ones((4, 4)) * 2.0}, "b": jnp.ones((4,))}
    state = init_state(params)
    cfg = AdamWConfig(weight_decay=0.0)

    def loss(p):
        return jnp.sum(p["lin"]["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = loss(params)
    for i in range(50):
        g = jax.grad(loss)(params)
        params, state, m = apply_updates(params, g, state, 0.05, cfg)
    assert float(loss(params)) < float(l0) * 0.2
    assert int(state["step"]) == 50
    assert np.isfinite(float(m["grad_norm"]))


def test_weight_decay_only_on_matrices():
    params = {"lin": {"w": jnp.ones((4, 4))}, "norm": jnp.ones((4,))}
    state = init_state(params)
    cfg = AdamWConfig(weight_decay=0.5)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = apply_updates(params, zero_g, state, 0.1, cfg)
    assert float(jnp.max(jnp.abs(p2["norm"] - 1.0))) < 1e-6  # no decay
    assert float(p2["lin"]["w"][0, 0]) < 1.0                  # decayed


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(gn) == pytest.approx(np.sqrt(1000.0), rel=1e-5)


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.int32(s), 1e-3, 10, 100)) for s in range(100)]
    assert lrs[0] < lrs[9]                    # warmup rises
    assert max(lrs) == pytest.approx(1e-3, rel=1e-3)
    assert lrs[-1] < 0.3e-3                   # decays


# ------------------------------------------------------------------- data
def test_data_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=8)
    ds = SyntheticLM(cfg)
    a1, b1 = ds.batch(step=7, shard=0, num_shards=2)
    a2, b2 = ds.batch(step=7, shard=0, num_shards=2)
    np.testing.assert_array_equal(a1, a2)
    assert a1.shape == (4, 64)
    np.testing.assert_array_equal(a1[:, 1:], b1[:, :-1])  # targets shifted


def test_data_shards_disjoint_streams():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    ds = SyntheticLM(cfg)
    a0, _ = ds.batch(3, shard=0, num_shards=2)
    a1, _ = ds.batch(3, shard=1, num_shards=2)
    assert not np.array_equal(a0, a1)


def test_data_has_planted_structure():
    cfg = DataConfig(vocab_size=50_000, seq_len=512, global_batch=4)
    ds = SyntheticLM(cfg)
    toks, _ = ds.batch(0)
    d = cfg.copy_dist
    match = (toks[:, d:] == toks[:, :-d]).mean()
    assert match > 0.2  # ~copy_prob plus chance collisions


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = {"p": {"w": jnp.arange(6.0).reshape(2, 3)}, "s": jnp.int32(3)}
    mgr.save(10, tree, blocking=True)
    like = jax.tree.map(np.zeros_like, tree)
    restored, step = mgr.restore(like)
    assert step == 10
    np.testing.assert_array_equal(restored["p"]["w"], np.asarray(tree["p"]["w"]))


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), max_to_keep=2)
    tree = {"w": jnp.ones((64, 64))}
    for s in (1, 2, 3, 4):
        mgr.save(s, jax.tree.map(lambda x: x * s, tree))
    mgr.wait()
    assert mgr.complete_steps() == [3, 4]
    restored, step = mgr.restore(jax.tree.map(np.zeros_like, tree))
    assert step == 4
    assert float(restored["w"][0, 0]) == 4.0


def test_checkpoint_crash_consistency(tmp_path):
    """A step dir without MANIFEST (simulated mid-save crash) is ignored."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = {"w": jnp.ones((4,))}
    mgr.save(1, tree, blocking=True)
    # simulate crash during step 2: shard written, no manifest
    d = os.path.join(str(tmp_path), "step_00000002")
    os.makedirs(d)
    np.savez(os.path.join(d, "shard_0.npz"), w=np.zeros(4))
    restored, step = mgr.restore(jax.tree.map(np.zeros_like, tree))
    assert step == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, {"w": jnp.ones((4,))}, blocking=True)
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore({"w": np.zeros((5,))})
