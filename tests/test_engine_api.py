"""Redesigned serving API: EngineConfig + RequestHandle + the facade.

The contract under test:

  * `EngineConfig` is the ONLY constructor surface — frozen, validated in
    one place (`__post_init__`), round-trippable via `replace()`.
  * The legacy kwargs form (`ServeEngine("qwen2-7b", slots=...)`) still
    works through a deprecation shim and is PINNED to produce an identical
    `engine_step_signature` and bit-identical token streams.
  * `submit()` returns a `RequestHandle` whose `.status` walks
    queued -> prefill -> decode -> finished (PREEMPTED covered in
    tests/test_preemption.py), consistent with the PR 7 trace-span
    lifecycle model.
  * `repro.serving` is the stable import facade.
  * The asyncio front end (`repro.launch.frontend`) serves the engine over
    HTTP + SSE with nothing beyond the stdlib.
"""

import asyncio
import dataclasses
import json
import warnings

import numpy as np
import pytest

from repro.serving import (
    CacheConfig,
    EngineConfig,
    ObsConfig,
    RequestHandle,
    SamplingParams,
    ServeEngine,
    ServeFrontend,
)

ARCH = "qwen2-7b"
SCHEME = "fp5.33-e2m3"


def small_config(**kw):
    base = dict(arch=ARCH, scheme=SCHEME, slots=2, capacity=48,
                cache=CacheConfig(kind="paged_ams", page_size=8))
    base.update(kw)
    return EngineConfig(**base)


# ================================================================ EngineConfig
class TestEngineConfig:
    def test_frozen_and_replace_round_trip(self):
        ec = small_config()
        with pytest.raises(dataclasses.FrozenInstanceError):
            ec.slots = 8
        ec2 = ec.replace(slots=4).replace(slots=ec.slots)
        assert ec2 == ec

    def test_single_validation_surface(self):
        # every invalid field errors at CONSTRUCTION, not at first use
        for kw in (dict(arch="no-such-arch"), dict(slots=0),
                   dict(capacity=0), dict(prefill_chunk=0),
                   dict(speculate_k=-1), dict(token_budget=0),
                   dict(max_queue=0), dict(cache=42), dict(obs=42)):
            with pytest.raises((ValueError, TypeError)):
                small_config(**kw)

    def test_step_chunk_covers_speculation(self):
        assert small_config(prefill_chunk=4).step_chunk == 4
        # a k-draft round feeds k+1 positions: the buffer must cover it
        assert small_config(speculate_k=4).step_chunk == 5
        assert small_config(prefill_chunk=8, speculate_k=4).step_chunk == 8

    def test_from_legacy_maps_and_warns(self):
        with pytest.warns(DeprecationWarning):
            ec = EngineConfig.from_legacy(
                ARCH, scheme=SCHEME, slots=2, capacity=48,
                cache_config=CacheConfig(kind="paged_ams", page_size=8))
        assert ec == small_config()
        with pytest.raises(TypeError, match="no_such_kwarg"):
            EngineConfig.from_legacy(ARCH, no_such_kwarg=1, _warn=False)

    def test_constructor_rejects_config_plus_kwargs(self):
        with pytest.raises(TypeError, match="no extra keyword"):
            ServeEngine(small_config(), slots=4)


class TestLegacyShimEquivalence:
    def test_signature_and_streams_pinned(self):
        """The shim path must build the SAME engine: equal step signature
        (compilation identity) and bit-identical greedy streams."""
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            legacy = ServeEngine(ARCH, scheme=SCHEME, slots=2, capacity=48,
                                 cache_config=CacheConfig(kind="paged_ams",
                                                          page_size=8))
            assert any(issubclass(x.category, DeprecationWarning)
                       for x in w)
        new = ServeEngine(small_config())
        assert legacy.signature == new.signature
        prompt = np.arange(1, 11, dtype=np.int32)
        a = legacy.submit(prompt, max_tokens=6).result()
        b = new.submit(prompt, max_tokens=6).result()
        assert a == b


# =============================================================== RequestHandle
class TestRequestHandle:
    def test_lifecycle_matches_trace_spans(self):
        """`.status` must agree with the PR 7 span model at every stage:
        the observable status sequence IS the span sequence."""
        eng = ServeEngine(small_config(slots=1, obs=ObsConfig(trace=True)))
        h1 = eng.submit(np.arange(1, 10, dtype=np.int32), max_tokens=4)
        h2 = eng.submit(np.arange(2, 11, dtype=np.int32), max_tokens=4)
        assert (h1.status, h2.status) == ("queued", "queued")
        seen = {h1.status, h2.status}
        while eng.has_work:
            eng.step()
            seen.update((h1.status, h2.status))
        assert h1.status == h2.status == "finished"
        assert seen == {"queued", "prefill", "decode", "finished"}
        from repro.obs import validate_events
        spans = validate_events(eng.trace.events())
        for h in (h1, h2):
            names = [n for n, _, _, _ in spans[h.request.rid + 1]]
            assert names == ["queued", "prefill", "decode", "request"]

    def test_result_and_tokens_so_far(self):
        eng = ServeEngine(small_config())
        h = eng.submit(np.arange(1, 8, dtype=np.int32), max_tokens=5)
        assert isinstance(h, RequestHandle)
        assert h.tokens_so_far() == [] and not h.done
        out = h.result()        # drives the engine itself (no driver loop)
        assert len(out) == 5 and h.done
        assert h.tokens_so_far() == out
        assert h.request.finish_reason in ("stop", "length")

    def test_async_stream_yields_every_token(self):
        eng = ServeEngine(small_config())
        ref = ServeEngine(small_config()).submit(
            np.arange(1, 8, dtype=np.int32), max_tokens=5).result()
        h = eng.submit(np.arange(1, 8, dtype=np.int32), max_tokens=5)

        async def collect():
            return [t async for t in h.stream()]

        assert asyncio.run(collect()) == ref

    def test_seeded_sampling_replays(self):
        sp = SamplingParams(temperature=0.8, top_k=16, seed=7)
        outs = [ServeEngine(small_config()).submit(
                    np.arange(1, 9, dtype=np.int32), max_tokens=6,
                    sampling=sp).result()
                for _ in range(2)]
        assert outs[0] == outs[1]


# ====================================================================== facade
def test_facade_exports():
    import repro.serving as serving
    for name in serving.__all__:
        assert getattr(serving, name) is not None
    # the facade re-exports the SAME objects, not copies
    from repro.launch.engine import ServeEngine as inner
    assert serving.ServeEngine is inner


# ==================================================================== frontend
class TestFrontend:
    @pytest.fixture()
    def served(self):
        eng = ServeEngine(small_config(max_queue=4))
        fe = ServeFrontend(eng)
        loop = asyncio.new_event_loop()
        loop.run_until_complete(fe.start())
        yield fe, loop
        loop.run_until_complete(fe.stop())
        loop.close()

    def _roundtrip(self, fe, loop, method, path, payload=None):
        async def go():
            r, w = await asyncio.open_connection("127.0.0.1", fe.port)
            body = json.dumps(payload).encode() if payload is not None else b""
            w.write(f"{method} {path} HTTP/1.1\r\n"
                    f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
            await w.drain()
            raw = (await r.read()).decode()
            w.close()
            return raw
        return loop.run_until_complete(go())

    def test_generate_matches_direct_engine(self, served):
        fe, loop = served
        ref = ServeEngine(small_config()).submit(
            np.arange(1, 11, dtype=np.int32), max_tokens=6).result()
        raw = self._roundtrip(fe, loop, "POST", "/v1/generate",
                              {"prompt": list(range(1, 11)),
                               "max_tokens": 6})
        head, _, payload = raw.partition("\r\n\r\n")
        assert "200 OK" in head
        assert json.loads(payload)["tokens"] == ref

    def test_sse_stream_matches_direct_engine(self, served):
        fe, loop = served
        ref = ServeEngine(small_config()).submit(
            np.arange(1, 11, dtype=np.int32), max_tokens=6).result()
        raw = self._roundtrip(fe, loop, "POST", "/v1/generate",
                              {"prompt": list(range(1, 11)),
                               "max_tokens": 6, "stream": True})
        assert "text/event-stream" in raw
        toks = [json.loads(ln[6:])["token"] for ln in raw.splitlines()
                if ln.startswith("data: {\"token\"")]
        assert toks == ref
        assert "event: done" in raw

    def test_healthz_metrics_and_errors(self, served):
        fe, loop = served
        assert '"ok": true' in self._roundtrip(fe, loop, "GET", "/healthz")
        m = self._roundtrip(fe, loop, "GET", "/metrics")
        assert "serve_requests_finished_total" in m
        assert "400" in self._roundtrip(fe, loop, "POST", "/v1/generate",
                                        {"prompt": "not-token-ids"})
        assert "404" in self._roundtrip(fe, loop, "GET", "/nope")

    def test_queue_full_returns_429(self, served):
        fe, loop = served

        async def burst():
            async def one(i):
                r, w = await asyncio.open_connection("127.0.0.1", fe.port)
                body = json.dumps({"prompt": [1 + i, 2, 3],
                                   "max_tokens": 8}).encode()
                w.write(b"POST /v1/generate HTTP/1.1\r\n"
                        b"Content-Length: %d\r\n\r\n%s" % (len(body), body))
                await w.drain()
                raw = (await r.read()).decode()
                w.close()
                return raw
            return await asyncio.gather(*[one(i) for i in range(12)])

        results = loop.run_until_complete(burst())
        codes = [r.split(" ", 2)[1] for r in results]
        # max_queue=4 + 2 slots: the burst MUST shed load with 429s and
        # still serve every accepted request to completion (the exact
        # accept count depends on driver/submission interleaving)
        assert codes.count("429") >= 1
        assert codes.count("200") >= 4
        assert codes.count("200") + codes.count("429") == len(codes)
        for r in results:
            if r.startswith("HTTP/1.1 200"):
                assert len(json.loads(r.partition("\r\n\r\n")[2])["tokens"]) == 8
