"""Ragged multi-token engine step: chunked prefill through the decode path.

The load-bearing acceptance oracle: greedy token streams are IDENTICAL to
the one-token-per-tick engine across every cache mode (contiguous /
paged-bf16 / paged-AMS) and chunk size, while prompt-prefill tick counts
drop ~C×. Plus: the per-tick token budget guarantees decode slots advance
every tick under a long chunking prefill (no starvation), budget-aware
admission, the multi-token page scatter, and the multi-query Pallas kernel
against the ref oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import (
    CacheConfig,
    make_gqa_page_pool,
    paged_attend,
    paged_attention_ref,
    paged_insert,
)
from repro.launch.engine import ServeEngine
from repro.launch.scheduler import FIFOScheduler, Request
from repro.models.attention import chunk_lengths, kv_index_map

ARCH = "qwen2-7b"
SCHEME = "fp5.33-e2m3"
CAP = 32

CACHE_CFGS = {
    "contiguous": None,
    "paged_bf16": CacheConfig(kind="paged_bf16", page_size=8),
    "paged_ams": CacheConfig(kind="paged_ams", page_size=8),
}


def poisson_workload(n, seed=7, rate=0.5, prompt_mean=12, max_tokens=(3, 6)):
    rng = np.random.default_rng(seed)
    gaps = rng.geometric(rate, n)
    arrivals = np.cumsum(gaps) - gaps[0]
    return [(int(t),
             rng.integers(0, 512, max(1, int(rng.poisson(prompt_mean)))),
             int(rng.integers(*max_tokens)))
            for t in arrivals]


def drive(eng, work):
    reqs, pending = [], list(work)
    while pending or eng.has_work:
        while pending and pending[0][0] <= eng.tick:
            _, prompt, mt = pending.pop(0)
            reqs.append(eng.submit(prompt, mt))
        eng.step()
    assert all(r.done for r in reqs)
    return reqs


def engine(mode, chunk=1, **kw):
    return ServeEngine(ARCH, scheme=SCHEME, slots=2, capacity=CAP, seed=0,
                       cache_config=CACHE_CFGS[mode], prefill_chunk=chunk,
                       **kw)


@pytest.fixture(scope="module")
def workload():
    return poisson_workload(4)


@pytest.fixture(scope="module")
def baseline_streams(workload):
    """One-token-per-tick (pre-refactor contract) streams per cache mode."""
    out = {}
    for mode in CACHE_CFGS:
        reqs = drive(engine(mode), workload)
        out[mode] = ([np.asarray(r.tokens) for r in reqs],
                     [r.prefill_ticks for r in reqs])
    return out


# ------------------------------------------------- token-stream equivalence
@pytest.mark.parametrize("mode", list(CACHE_CFGS))
@pytest.mark.parametrize("chunk", [4, CAP])
def test_chunked_stream_identical_to_one_token(mode, chunk, workload,
                                               baseline_streams):
    """C ∈ {1, 4, capacity} × {contiguous, paged-bf16, paged-AMS}: the
    ragged step's greedy streams equal the one-token engine's bit for bit
    (C=1 IS the baseline), and prefill consumes ~C× fewer ticks."""
    base_toks, base_pf = baseline_streams[mode]
    reqs = drive(engine(mode, chunk=chunk), workload)
    for j, r in enumerate(reqs):
        np.testing.assert_array_equal(
            np.asarray(r.tokens), base_toks[j],
            err_msg=f"{mode} C={chunk}: request {j} diverged")
    pf = [r.prefill_ticks for r in reqs]
    for j, (b, c) in enumerate(zip(base_pf, pf)):
        # one-token engine: prompt_len prefill ticks; ragged: ceil(len/C)
        assert c == -(-b // chunk), (mode, chunk, j, b, c)


def test_prefill_ticks_drop_4x_and_ttft_reported():
    """Acceptance pin: C=8 on a long prompt cuts prefill ticks >= 4x and
    TTFT percentiles land in stats()."""
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, 512, 24)
    base = engine("contiguous")
    r0 = base.submit(prompt, 4)
    base.run()
    ch = engine("contiguous", chunk=8)
    r1 = ch.submit(prompt, 4)
    ch.run()
    np.testing.assert_array_equal(np.asarray(r0.tokens), np.asarray(r1.tokens))
    pf0, pf1 = r0.prefill_ticks, r1.prefill_ticks
    assert pf0 == 24 and pf1 == 3           # ceil(24/8): 8x fewer
    assert pf0 >= 4 * pf1
    s = ch.stats()
    assert s["ttft_ticks_p50"] == r1.ttft_ticks
    assert s["latency_ticks_p50"] == r1.latency_ticks
    assert r1.ttft_ticks < r0.ttft_ticks


# ----------------------------------------------------- scheduling / budget
def test_decode_advances_every_tick_during_long_prefill():
    """No starvation: while a long prompt chunks through slot 1, the
    decoding request in slot 0 still gains exactly one token per tick."""
    rng = np.random.default_rng(9)
    eng = engine("contiguous", chunk=8)
    dec = eng.submit(rng.integers(0, 512, 1), 12)    # decodes from tick 1
    eng.step()                                       # consume 1-token prompt
    long = eng.submit(rng.integers(0, 512, 24), 4)
    while not long.done:
        before = len(dec.tokens)
        eng.step()
        if not dec.done:
            assert len(dec.tokens) == before + 1     # advanced this tick
    assert dec.done or len(dec.tokens) > 0
    eng.run()
    assert dec.done and long.done
    # the long prompt really chunked (3 prefill ticks, not 24)
    assert long.prefill_ticks == 3


def test_token_budget_throttles_chunks_not_liveness():
    """token_budget below slots*C: every active slot still advances >= 1
    token per tick; prefill chunks shrink to the leftover budget. With
    budget == active slots the ragged engine degenerates to one-token
    prefill (same stream, same tick count as C=1)."""
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, 512, 16)
    base = engine("contiguous")
    b0 = base.submit(prompt, 3)
    base.run()
    tight = engine("contiguous", chunk=8, token_budget=1)
    t0 = tight.submit(prompt, 3)
    tight.run()
    np.testing.assert_array_equal(np.asarray(b0.tokens), np.asarray(t0.tokens))
    assert t0.ttft_ticks == b0.ttft_ticks    # no budget left for chunking
    mid = engine("contiguous", chunk=8, token_budget=4)
    m0 = mid.submit(prompt, 3)
    mid.run()
    np.testing.assert_array_equal(np.asarray(b0.tokens), np.asarray(m0.tokens))
    # sole active slot: 1 guaranteed + 3 leftover = 4-token chunks
    assert m0.prefill_ticks == 4   # ceil(16/4)


def test_admit_is_token_budget_aware():
    """FIFOScheduler.admit(max_admit=...) caps admissions so active slots
    never exceed the per-tick token budget; the engine passes its headroom."""
    sched = FIFOScheduler(capacity=64)
    reqs = [sched.submit(Request(rid=i, prompt=np.arange(4) + 1,
                                 max_tokens=2), tick=0) for i in range(3)]
    placed = sched.admit([0, 1, 2], tick=0, max_admit=1)
    assert [s for s, _ in placed] == [0]
    assert sched.queue_depth == 2
    placed = sched.admit([1, 2], tick=1, max_admit=None)
    assert [s for s, _ in placed] == [1, 2]
    assert reqs[0].admit_tick == 0 and reqs[2].admit_tick == 1

    # engine-level: budget 1 on 2 slots -> second request waits in queue
    rng = np.random.default_rng(3)
    eng = engine("contiguous", chunk=4, token_budget=1)
    r0 = eng.submit(rng.integers(0, 512, 4), 2)
    r1 = eng.submit(rng.integers(0, 512, 4), 2)
    eng.step()
    assert r0.admit_tick == 0 and r1.admit_tick == -1
    assert eng.active_count == 1
    eng.run()
    assert r0.done and r1.done
    assert r1.admit_tick > r0.admit_tick


# ----------------------------------------------------- multi-token scatter
def test_paged_insert_chunk_equals_sequential():
    """One [B, C] block scatter == C single-token inserts, bit for bit, for
    bf16 and packed-AMS pools (suppressed tail entries included)."""
    rng = np.random.default_rng(1)
    B, kv, hd, c = 2, 2, 32, 4
    for kind in ("paged_bf16", "paged_ams"):
        ccfg = CacheConfig(kind=kind, page_size=4).sized(capacity=16, slots=B)
        pool0 = make_gqa_page_pool(ccfg, kv, hd)
        bt = jnp.asarray(
            rng.permutation(ccfg.num_pages)[:B * ccfg.max_pages_per_seq]
            .reshape(B, ccfg.max_pages_per_seq).astype(np.int32))
        start = jnp.asarray([3, 0], jnp.int32)
        nval = jnp.asarray([4, 2], jnp.int32)    # slot 1: ragged tail dropped
        k_new = jnp.asarray(rng.standard_normal((B, c, kv, hd)), jnp.bfloat16)
        v_new = jnp.asarray(rng.standard_normal((B, c, kv, hd)), jnp.bfloat16)
        pool_seq = pool0
        for j in range(c):
            pos_j = jnp.where(j < nval, start + j, -1)
            pool_seq = paged_insert(pool_seq, k_new[:, j:j + 1],
                                    v_new[:, j:j + 1], pos_j, bt, ccfg)
        pool_chunk = paged_insert(pool0, k_new, v_new, start, bt, ccfg,
                                  nvalid=nval)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), pool_seq, pool_chunk)


# --------------------------------------------- multi-query Pallas vs oracle
@pytest.mark.slow
def test_chunked_pallas_matches_ref_oracle():
    """The multi-query kernel (interpret mode) agrees with the chunked
    gather-dequantize-attend oracle per query row, ragged tails (length 0)
    flushing to exact zeros, for bf16 and AMS pools."""
    rng = np.random.default_rng(2)
    B, kv, hd, H, c = 2, 2, 32, 4, 4
    for kind, qdt, tol in (("paged_bf16", jnp.bfloat16, 0.0),
                           ("paged_ams", jnp.float32, 2e-6)):
        ccfg = CacheConfig(kind=kind, page_size=4).sized(capacity=16, slots=B)
        pool = make_gqa_page_pool(ccfg, kv, hd)
        bt = jnp.asarray(
            rng.permutation(ccfg.num_pages)[:B * ccfg.max_pages_per_seq]
            .reshape(B, ccfg.max_pages_per_seq).astype(np.int32))
        start = jnp.asarray([3, 0], jnp.int32)
        nval = jnp.asarray([4, 2], jnp.int32)
        k_new = jnp.asarray(rng.standard_normal((B, c, kv, hd)), jnp.bfloat16)
        v_new = jnp.asarray(rng.standard_normal((B, c, kv, hd)), jnp.bfloat16)
        pool = paged_insert(pool, k_new, v_new, start, bt, ccfg, nvalid=nval)
        q = jnp.asarray(rng.standard_normal((B, c, H, hd)), qdt)
        lengths = chunk_lengths(start, nval, c)
        kvm = kv_index_map(H, H, kv)
        o_ref = paged_attention_ref(q, pool, lengths, bt, ccfg, kv_map=kvm)
        ccfg_i = CacheConfig(kind=kind, page_size=4,
                             impl="pallas_interpret").sized(capacity=16,
                                                            slots=B)
        o_pal = paged_attend(q, pool, lengths, bt, ccfg_i, kv_map=kvm)
        if tol:
            np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                                       np.asarray(o_ref, np.float32),
                                       atol=tol, rtol=tol)
        else:   # bf16 pools: same pv rounding both sides at bf16 q
            np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                                       np.asarray(o_ref, np.float32),
                                       atol=2e-2, rtol=2e-2)
        # ragged tail rows (j >= nvalid) are exact zeros
        assert np.all(np.asarray(o_pal[1, 2:], np.float32) == 0)
