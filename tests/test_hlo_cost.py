"""Regression guards for the trip-count-aware HLO cost parser — the
foundation of the roofline deliverable (cost_analysis counts loop bodies
once; these tests pin our corrections)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_cost import module_cost, parse_module, Cost


def compile_text(fn, *shapes):
    return jax.jit(fn).lower(*shapes).compile().as_text()


def test_scan_trip_count_multiplied():
    def scanned(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    text = compile_text(
        scanned,
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((10, 128, 128), jnp.float32))
    c = module_cost(text)
    expect = 10 * 2 * 128 ** 3
    assert abs(c.flops / expect - 1) < 0.02, c.flops


def test_nested_scan_trips_compose():
    def nested(x, ws):
        def outer(c, _):
            def body(c2, w):
                return jnp.tanh(c2 @ w), None
            y, _ = jax.lax.scan(body, c, ws)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    text = compile_text(
        nested,
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((10, 128, 128), jnp.float32))
    c = module_cost(text)
    expect = 50 * 2 * 128 ** 3
    assert abs(c.flops / expect - 1) < 0.02


def test_plain_matmul_flops_and_bytes():
    def mm(a, b):
        return a @ b

    text = compile_text(mm, jax.ShapeDtypeStruct((256, 512), jnp.float32),
                        jax.ShapeDtypeStruct((512, 128), jnp.float32))
    c = module_cost(text)
    assert abs(c.flops / (2 * 256 * 512 * 128) - 1) < 0.02
    io_bytes = 4 * (256 * 512 + 512 * 128 + 256 * 128)
    assert io_bytes <= c.hbm_bytes <= 3 * io_bytes


def test_scanned_weight_slices_not_overcharged():
    """HBM model must charge dynamic-sliced scan inputs at slice size."""
    def scanned(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    text = compile_text(
        scanned,
        jax.ShapeDtypeStruct((8, 256), jnp.float32),
        jax.ShapeDtypeStruct((20, 256, 256), jnp.float32))
    c = module_cost(text)
    w_bytes = 20 * 256 * 256 * 4  # each weight read once
    assert c.hbm_bytes < 6 * w_bytes, c.hbm_bytes  # NOT 20x the stack


def test_module_parses_computation_regions():
    def f(x):
        return jax.lax.scan(lambda c, _: (jnp.sin(c), None), x, None,
                            length=3)[0]

    text = compile_text(f, jax.ShapeDtypeStruct((64,), jnp.float32))
    comps, entry = parse_module(text)
    assert entry is not None
    assert any("region" in n or "body" in n for n in comps), list(comps)
