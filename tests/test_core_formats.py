"""Unit tests for FP format definitions and decode."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats
from repro.core.formats import SCHEMES, code_to_value, get_format, mag_table


def test_paper_table1_e2m3():
    f = get_format("e2m3")
    assert f.bias == 1
    assert f.max_normal == 7.5
    # min normal S 001 000 = 2^0 * 1.0
    assert f.decode_mag(np.array([0b001000]))[0] == 1.0
    # max subnormal S 000 111 = 2^-1 * 0.875 wait: paper lists m=2 variant;
    # e2m3 subnormal max = 2^(1-1) * 7/8 = 0.875
    assert f.decode_mag(np.array([0b000111]))[0] == 0.875
    assert f.min_subnormal == 0.125


def test_paper_table1_e3m2():
    f = get_format("e3m2")
    assert f.bias == 3
    assert f.max_normal == 28.0
    assert f.decode_mag(np.array([0b00100]))[0] == 0.25  # min normal
    assert f.decode_mag(np.array([0b00011]))[0] == 0.1875  # max subnormal
    assert f.min_subnormal == 0.0625


def test_mag_table_monotone_all_formats():
    for f in formats.FORMATS.values():
        t = mag_table(f)
        assert np.all(np.diff(t) > 0)
        assert t[0] == 0.0
        assert t[-1] == np.float32(f.max_normal)


def test_code_to_value_matches_numpy_decode():
    for f in formats.FORMATS.values():
        mags = np.arange(f.num_mag_codes)
        # positive
        v = np.asarray(code_to_value(f, jnp.asarray(mags)))
        np.testing.assert_allclose(v, f.decode_mag(mags), rtol=0)
        # negative: set sign bit
        vneg = np.asarray(code_to_value(f, jnp.asarray(mags | (1 << f.code_bits))))
        np.testing.assert_allclose(vneg, -f.decode_mag(mags), rtol=0)


def test_effective_bits():
    assert SCHEMES["fp5.33-e2m3"].effective_bits == pytest.approx(5 + 1 / 3)
    assert SCHEMES["fp4.25-e2m2"].effective_bits == 4.25
    assert SCHEMES["fp4.5-e2m2"].effective_bits == 4.5
    assert SCHEMES["fp6-e2m3"].effective_bits == 6.0


def test_no_inf_nan_anywhere():
    for f in formats.FORMATS.values():
        all_codes = np.arange(1 << f.total_bits)
        v = np.asarray(code_to_value(f, jnp.asarray(all_codes)))
        assert np.all(np.isfinite(v))
