"""Unit + property tests for RTN, AMS sharing, adaptive search, packing."""

import jax.numpy as jnp
import numpy as np
import pytest

# Property tests need hypothesis; keep the rest of the suite collectable
# without it (it ships in the dev extras — see pyproject.toml).
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    SCHEMES,
    ams_quantize,
    ams_quantize_dequantize,
    code_to_value,
    dequantize,
    get_format,
    get_scheme,
    pack,
    quantize_linear,
    quantize_rtn,
    unpack,
)
from repro.core.ams import share_mantissa
from repro.core.qlinear import apply as qapply, dequantize_weight
from repro.core.rtn import table_values


def rand_w(K, N, seed=0, scale=0.02):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((K, N)).astype(np.float32) * scale)


# ----------------------------------------------------------------- RTN ----
def test_rtn_roundtrip_exact_on_grid():
    """Values already on the format grid must round-trip exactly."""
    f = get_format("e2m3")
    vals = table_values(f)  # all representable values, scale 1
    w = jnp.asarray(np.tile(vals[:, None], (1, 3)))
    # force scale = 1 by adding max_normal row
    codes, scale = quantize_rtn(w, f)
    np.testing.assert_allclose(np.asarray(scale), 1.0)
    wq = dequantize(codes, f, scale)
    np.testing.assert_allclose(np.asarray(wq), np.asarray(w))


def test_rtn_error_bounded_by_half_ulp():
    f = get_format("e2m2")
    w = rand_w(256, 16, seed=1)
    codes, scale = quantize_rtn(w, f)
    wq = np.asarray(dequantize(codes, f, scale))
    wn = np.asarray(w) / np.asarray(scale)
    # max gap between adjacent representable magnitudes at the top of range
    t = np.asarray(table_values(f))
    max_gap = np.max(np.diff(t))
    assert np.all(np.abs(wq / np.asarray(scale) - wn) <= max_gap / 2 + 1e-6)


def test_rtn_scale_is_per_output_channel():
    f = get_format("e2m3")
    w = rand_w(64, 8, seed=2)
    w = w.at[:, 3].mul(100.0)
    _, scale = quantize_rtn(w, f)
    assert np.asarray(scale)[3] > 10 * np.asarray(scale)[0]


@settings(deadline=None, max_examples=25)
@given(
    st.sampled_from(["e2m1", "e2m2", "e2m3", "e3m2", "e4m3"]),
    st.integers(0, 2**31 - 1),
)
def test_rtn_idempotent_property(fmt_name, seed):
    """Property: quantizing an already-quantized tensor is a fixed point."""
    f = get_format(fmt_name)
    w = rand_w(32, 4, seed=seed % 10_000)
    codes, scale = quantize_rtn(w, f)
    wq = dequantize(codes, f, scale)
    codes2, scale2 = quantize_rtn(wq, f)
    wq2 = dequantize(codes2, f, scale2)
    np.testing.assert_allclose(np.asarray(wq2), np.asarray(wq), rtol=1e-6, atol=1e-9)


# ----------------------------------------------------------------- AMS ----
@pytest.mark.parametrize("scheme", ["fp5.33-e2m3", "fp4.5-e2m2", "fp4.33-e2m2", "fp4.25-e2m2"])
@pytest.mark.parametrize("strategy", ["set_lsb", "requantize"])
def test_shared_lsb_constant_within_group(scheme, strategy):
    s = get_scheme(scheme)
    w = rand_w(s.k * 64, 16, seed=3)
    codes, _ = ams_quantize(w, s, strategy)
    bits = np.asarray(codes) & 1
    g = bits.reshape(-1, s.k, 16)
    assert np.all(g == g[:, :1, :])


@pytest.mark.parametrize("scheme", ["fp5.33-e2m3", "fp4.25-e2m2"])
def test_adaptive_search_beats_fixed_lsb(scheme):
    """Adaptive search must be no worse than forcing LSB=0 or LSB=1."""
    s = get_scheme(scheme)
    w = rand_w(s.k * 128, 32, seed=4)
    wq = ams_quantize_dequantize(w, s, "set_lsb")
    mse_adaptive = float(jnp.mean((wq - w) ** 2))
    codes, scale = quantize_rtn(w, s.base)
    for forced in (0, 1):
        fc = (codes & ~jnp.int32(1)) | forced
        mse_forced = float(jnp.mean((dequantize(fc, s.base, scale) - w) ** 2))
        assert mse_adaptive <= mse_forced + 1e-12


def test_requantize_no_worse_than_set_lsb():
    for name in ("fp5.33-e2m3", "fp4.5-e2m2", "fp4.25-e2m2"):
        s = get_scheme(name)
        w = rand_w(s.k * 96, 24, seed=5)
        m_set = float(jnp.mean((ams_quantize_dequantize(w, s, "set_lsb") - w) ** 2))
        m_req = float(jnp.mean((ams_quantize_dequantize(w, s, "requantize") - w) ** 2))
        assert m_req <= m_set + 1e-12


def test_mse_ordering_matches_paper():
    """Fig.3/5 ordering: fp6 <= fp5.33 <= fp5 <= fp4.5 <= fp4.25 <= fp4."""
    w = rand_w(960, 64, seed=6)
    order = ["fp6-e2m3", "fp5.33-e2m3", "fp5-e2m2", "fp4.5-e2m2", "fp4.25-e2m2", "fp4-e2m1"]
    mses = [
        float(jnp.mean((ams_quantize_dequantize(w, SCHEMES[n]) - w) ** 2))
        for n in order
    ]
    assert mses == sorted(mses), dict(zip(order, mses))


@settings(deadline=None, max_examples=20)
@given(st.sampled_from(["fp5.33-e2m3", "fp4.5-e2m2", "fp4.25-e2m2"]), st.integers(0, 9999))
def test_ams_error_bounded_property(scheme_name, seed):
    """Sharing can cost at most one LSB step per weight (requantize path)."""
    s = get_scheme(scheme_name)
    f = s.base
    w = rand_w(s.k * 32, 8, seed=seed)
    codes, scale = ams_quantize(w, s, "requantize")
    wq = np.asarray(dequantize(codes, f, scale))
    wn = np.abs(np.asarray(w) / np.asarray(scale))
    t = np.asarray(table_values(f))
    # worst case: nearest point on the coarser (every-other) sub-lattice
    max_gap = np.max(np.diff(t[t >= 0]))  # top-of-range gap of full lattice
    err = np.abs(wq / np.asarray(scale) - np.asarray(w) / np.asarray(scale))
    assert np.all(err <= 2 * max_gap)  # 2x full-lattice gap = sub-lattice half-gap bound


# ------------------------------------------------------------- packing ----
@pytest.mark.parametrize("scheme", list(SCHEMES))
def test_pack_unpack_roundtrip(scheme):
    s = SCHEMES[scheme]
    K = s.k * 3 * 32 * 4  # generous multiple
    w = rand_w(K, 24, seed=7)
    codes, scale = ams_quantize(w, s)
    p = pack(codes, scale, s)
    np.testing.assert_array_equal(np.asarray(unpack(p)), np.asarray(codes))


def test_fp533_fused_container_bit_exact_bits():
    s = SCHEMES["fp5.33-e2m3"]
    from repro.core.packing import make_layout

    lay = make_layout(s)
    assert lay.container == "fp533"
    # 6144 x 6144: exactly 16/3 bits per weight, zero waste
    assert lay.effective_bits(6144, 6144) == pytest.approx(16 / 3)


def test_planes_effective_bits_at_scale():
    from repro.core.packing import make_layout

    lay = make_layout(SCHEMES["fp4.25-e2m2"])
    assert lay.effective_bits(4096, 4096) == pytest.approx(4.25)


@settings(deadline=None, max_examples=15)
@given(
    st.sampled_from(list(SCHEMES)),
    st.integers(1, 300),
    st.integers(1, 8),
    st.integers(0, 9999),
)
def test_quantize_linear_handles_ragged_k(scheme_name, K, N, seed):
    """Property: any (K, N) works — padding is an exact no-op in the matmul."""
    s = SCHEMES[scheme_name]
    w = rand_w(K, N, seed=seed)
    q = quantize_linear(w, s)
    wd = dequantize_weight(q, dtype=jnp.float32)
    assert wd.shape == (K, N)
    x = rand_w(4, K, seed=seed + 1, scale=1.0)
    y = qapply(q, x, impl="ref")
    expect = x @ wd
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect), rtol=1e-5, atol=1e-6)


def test_quantized_linear_with_bias():
    s = SCHEMES["fp4.25-e2m2"]
    w = rand_w(256, 32, seed=8)
    b = jnp.arange(32, dtype=jnp.float32)
    q = quantize_linear(w, s, bias=b)
    x = rand_w(2, 256, seed=9, scale=1.0)
    y = qapply(q, x)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x @ dequantize_weight(q, jnp.float32) + b),
        rtol=1e-5, atol=1e-6,
    )
