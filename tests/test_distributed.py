"""Multi-device correctness tests (8 forced host CPU devices, subprocess).

Each test spawns a fresh python with XLA_FLAGS so the device count is set
before jax initializes (process-global). Covers the distribution machinery
the dry-run exercises at 512 devices:

  * MoE: dense oracle == TP path == EP (shard_map) path
  * flash-decode with sequence-sharded KV cache == unsharded reference
  * int8-compressed all-reduce == plain psum (within int8 grid error)
  * sharded train_step == single-device train_step (loss trajectory)
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, devices: int = 8, timeout: int = 420) -> str:
    code = ("import os\n"
            f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env, cwd=REPO)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_moe_paths_agree():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_test_mesh, use_mesh
    from repro.models.parallel import ParallelCtx
    from repro.models import moe as M
    from repro.configs import get_config

    cfg = get_config('dbrx-132b').reduced(num_layers=1, num_experts=4,
                                          experts_per_token=2, d_model=64,
                                          d_ff=128)
    import dataclasses
    cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)  # no drops
    mesh = make_test_mesh((2, 4), ('data', 'model'))
    ctx = ParallelCtx(mesh=mesh, dp_axes=('data',), tp_axis='model')
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 64))
    with use_mesh(mesh):
        y_dense, aux_d = M.moe_dense(p, x, cfg)
        y_tp, aux_t = jax.jit(lambda p, x: M.moe_tp(p, x, cfg, ctx))(p, x)
        y_ep, aux_e = jax.jit(lambda p, x: M.moe_ep(p, x, cfg, ctx))(p, x)
    np.testing.assert_allclose(np.asarray(y_tp), np.asarray(y_dense),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dense),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux_t), float(aux_d), rtol=1e-5)
    print('moe paths agree')
    """)


def test_flash_decode_seq_sharded():
    run_py("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_test_mesh, use_mesh
    from repro.models import attention as A

    mesh = make_test_mesh((2, 4), ('data', 'model'))
    B, S, KV, HD, H = 4, 64, 2, 16, 8
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, H, HD)), jnp.float32)
    ck = jnp.asarray(rng.standard_normal((B, S, KV, HD)), jnp.float32)
    cv = jnp.asarray(rng.standard_normal((B, S, KV, HD)), jnp.float32)
    kn = jnp.asarray(rng.standard_normal((B, 1, KV, HD)), jnp.float32)
    vn = jnp.asarray(rng.standard_normal((B, 1, KV, HD)), jnp.float32)
    pos = jnp.int32(37)
    kvm = A.kv_index_map(H, H, KV)

    core = functools.partial(A.gqa_decode_core, kv_map=kvm)
    o_ref, ck_ref, cv_ref = core(q, kn, vn, ck, cv, pos)

    from repro.launch.mesh import compat_shard_map
    sharded = compat_shard_map(
        functools.partial(core, axis_name='model'), mesh, {'model'},
        in_specs=(P(None, None, None), P(None, None, None, None),
                  P(None, None, None, None), P(None, 'model', None, None),
                  P(None, 'model', None, None), P()),
        out_specs=(P(None, None, None), P(None, 'model', None, None),
                   P(None, 'model', None, None)))
    with use_mesh(mesh):
        o_s, ck_s, cv_s = jax.jit(sharded)(q, kn, vn, ck, cv, pos)
    np.testing.assert_allclose(np.asarray(o_s), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(ck_s), np.asarray(ck_ref))
    print('flash decode sharded == ref')
    """)


def test_ring_cache_decode_sharded():
    run_py("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_test_mesh, use_mesh
    from repro.models import attention as A

    mesh = make_test_mesh((2, 4), ('data', 'model'))
    B, W, KV, HD, H = 2, 32, 1, 8, 4
    rng = np.random.default_rng(1)
    ck = jnp.asarray(rng.standard_normal((B, W, KV, HD)), jnp.float32)
    cv = jnp.asarray(rng.standard_normal((B, W, KV, HD)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, H, HD)), jnp.float32)
    kn = jnp.asarray(rng.standard_normal((B, 1, KV, HD)), jnp.float32)
    vn = jnp.asarray(rng.standard_normal((B, 1, KV, HD)), jnp.float32)
    pos = jnp.int32(100)  # deep past the window
    kvm = A.kv_index_map(H, H, KV)
    core = functools.partial(A.gqa_decode_core, kv_map=kvm, window=W, ring=True)
    o_ref, *_ = core(q, kn, vn, ck, cv, pos)
    from repro.launch.mesh import compat_shard_map
    sharded = compat_shard_map(functools.partial(core, axis_name='model'),
        mesh, {'model'},
        in_specs=(P(None,None,None), P(None,None,None,None), P(None,None,None,None),
                  P(None,'model',None,None), P(None,'model',None,None), P()),
        out_specs=(P(None,None,None), P(None,'model',None,None), P(None,'model',None,None)))
    with use_mesh(mesh):
        o_s, *_ = jax.jit(sharded)(q, kn, vn, ck, cv, pos)
    np.testing.assert_allclose(np.asarray(o_s), np.asarray(o_ref), rtol=2e-5, atol=2e-5)
    print('ring cache sharded == ref')
    """)


def test_int8_compressed_allreduce():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_test_mesh, use_mesh
    from repro.optim import compressed_allreduce

    mesh = make_test_mesh((8,), ('pod',))
    g = {'w': jnp.asarray(np.random.default_rng(0).standard_normal(1024),
                          jnp.float32),
         'tiny': jnp.ones((3,), jnp.float32)}
    with use_mesh(mesh):
        out = jax.jit(lambda g: compressed_allreduce(g, mesh, ('pod',)))(g)
    # psum over replicated = x * 8
    expect = g['w'] * 8
    err = np.abs(np.asarray(out['w']) - np.asarray(expect))
    # int8 grid error bound: 8 * amax/127/2 per shard after reduce
    amax = float(jnp.max(jnp.abs(expect)))
    assert err.max() <= amax / 127 + 1e-5, err.max()
    np.testing.assert_allclose(np.asarray(out['tiny']), 8.0)
    print('compressed allreduce ok')
    """)


def test_sharded_train_matches_single_device():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_test_mesh, use_mesh
    from repro.launch.steps import build_train_step
    from repro.configs import get_config
    from repro.configs.base import RunConfig
    from repro.models import init_params
    from repro.optim import init_state
    from repro.data import DataConfig, SyntheticLM

    cfg = get_config('qwen2-7b').reduced()
    losses = {}
    for kind, shape in [('multi', (2, 4)), ('single', (1, 1))]:
        mesh = make_test_mesh(shape, ('data', 'model'))
        rcfg = RunConfig(model=cfg, seq_len=32, global_batch=4, mode='train',
                         learning_rate=1e-3, warmup_steps=2)
        with use_mesh(mesh):
            f, shapes, shards = build_train_step(mesh, cfg, rcfg)
            params = init_params(jax.random.PRNGKey(0), cfg,
                                 tp=mesh.shape['model'])
            params = jax.device_put(params, shards['params'])
            opt = jax.device_put(init_state(params), shards['opt_state'])
            data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                          seq_len=32, global_batch=4))
            ls = []
            pre = jnp.zeros((4, 0, cfg.d_model), jnp.float32)
            for s in range(4):
                t, g = data.batch(s)
                params, opt, m = f(params, opt, jnp.asarray(t),
                                   jnp.asarray(g), pre, jnp.int32(s))
                ls.append(float(m['loss']))
            losses[kind] = ls
    # different tp padding => params differ; losses should still be close in
    # trajectory since padded heads are dead and vocab mask exact
    np.testing.assert_allclose(losses['multi'], losses['single'],
                               rtol=2e-2, atol=2e-2)
    print('sharded vs single loss:', losses)
    """, timeout=600)
