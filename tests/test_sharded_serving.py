"""Tensor-parallel sharded serving: sharded streams == single-device streams.

The house invariant for the TP engine (`ServeEngine(mesh=...)`): a paged
engine step sharded over the model axis — serving weight layout
(`sharding.params_shardings(serve_n_shard=True)`), head-sharded page pools
(`sharding.pool_shardings` + the shard_map wrap in `models.transformer`),
replicated residual/logits pins — emits BIT-IDENTICAL token streams to the
tp=1 engine, across cache formats, chunk widths and sampling/speculative
epilogues. The reduced qwen2 geometry is tp-invariant for tp in {1, 2}
(heads/kv/vocab all divide), so ONE params tree drives both engines and any
stream drift is a real numerics change, not a shape artifact.

Everything multi-device runs in a `run_py` subprocess (fresh python with
XLA_FLAGS=--xla_force_host_platform_device_count set before jax init —
same machinery as tests/test_distributed.py), so these tests exercise
`mesh.compat_shard_map` on whatever jax the environment resolves.

Also pinned here:
  * the host side is device-count-agnostic: page ids (block-table rows),
    prefix-cache hits and allocator stats are identical under any mesh
  * zero cross-device KV-page movement: no collective in the lowered tp=2
    step touches an operand with the pool's (num_pages, page_size) dims
  * the per-device accounting: `kv_bytes_per_token` and the cost-model KV
    floors scale as 1/tp on a head-sharded mesh (`obs.cost` kv_shards)
  * autotune keys: plans are keyed on local kv-head count + VMEM budget
"""

import textwrap

import pytest

from test_distributed import run_py

# Subprocess preamble shared by every multi-device test: a reduced qwen2
# engine factory driving a fixed two-request workload. The SAME f32 params
# tree (quantized identically inside each engine) feeds tp=1 and tp>1.
PREAMBLE = """
import jax, numpy as np
from repro.cache import CacheConfig, prefix_page_hashes
from repro.launch.engine import ServeEngine
from repro.launch.mesh import make_driver_mesh, make_serving_mesh, make_test_mesh
from repro.launch.sampling import SamplingParams
from repro.configs import get_config
from repro.models import init_params

CFG = get_config('qwen2-7b').reduced()
PARAMS = init_params(jax.random.PRNGKey(0), CFG, tp=1)

def make_engine(mesh, scheme, kind, chunk, k=0):
    return ServeEngine('qwen2-7b', reduced=True, scheme=scheme,
                       slots=2, capacity=32,
                       cache_config=CacheConfig(kind=kind, page_size=8),
                       prefill_chunk=chunk, speculate_k=k, mesh=mesh,
                       params=jax.tree.map(lambda x: x, PARAMS), seed=0)

def drive(eng, mode):
    samp = None
    if mode == 'sampled':
        samp = SamplingParams(temperature=0.8, top_p=0.9, seed=123)
    eng.submit([3, 5, 7], max_tokens=6, sampling=samp)
    eng.submit([3, 5, 11, 13, 2, 9], max_tokens=6, sampling=samp)
    st = eng.run()
    toks = [list(map(int, r.tokens)) for r in eng.finished]
    return toks, st

def assert_stream_equal(cell, a, b):
    (t1, s1), (t2, s2) = a, b
    assert t1 == t2, f'{cell}: token streams differ\\n tp1={t1}\\n tp2={t2}'
    for key in ('ticks', 'ttft_ticks_p50', 'latency_ticks_p50'):
        assert s1[key] == s2[key], (cell, key, s1[key], s2[key])
"""


def _run(body, **kw):
    """run_py with the shared preamble; dedents `body` here because the
    concatenation with the flush-left PREAMBLE defeats run_py's dedent."""
    return run_py(PREAMBLE + textwrap.dedent(body), **kw)


def test_sharded_streams_bit_identical_fast():
    """Representative cells of the equivalence grid on a (1, 2) mesh —
    both cache formats, both chunk widths, all three epilogues. One
    subprocess amortizes jax startup across the cells; the FULL
    {format} x {chunk} x {mode} grid runs in the slow-marked test."""
    _run("""
    CELLS = [('fp16', 'paged_bf16', 1, 0, 'greedy'),
             ('fp5.33-e2m3', 'paged_ams', 4, 0, 'greedy'),
             ('fp5.33-e2m3', 'paged_ams', 4, 0, 'sampled'),
             ('fp5.33-e2m3', 'paged_ams', 4, 2, 'spec')]
    for scheme, kind, chunk, k, mode in CELLS:
        cell = f'{kind}/chunk{chunk}/{mode}'
        a = drive(make_engine(make_driver_mesh('none'), scheme, kind, chunk, k), mode)
        b = drive(make_engine(make_serving_mesh(2), scheme, kind, chunk, k), mode)
        assert_stream_equal(cell, a, b)
        print('ok', cell)
    """, devices=2, timeout=600)


@pytest.mark.slow
def test_sharded_streams_bit_identical_full_grid():
    """The full house-invariant grid: {paged_bf16, paged_ams} x chunk
    {1, 4} x {greedy, seeded sampling, speculative k=2} — every cell's
    sharded stream bit-identical to single-device."""
    _run("""
    for kind, scheme in [('paged_bf16', 'fp16'), ('paged_ams', 'fp5.33-e2m3')]:
        for chunk in (1, 4):
            for mode, k in [('greedy', 0), ('sampled', 0), ('spec', 2)]:
                cell = f'{kind}/chunk{chunk}/{mode}'
                a = drive(make_engine(make_driver_mesh('none'), scheme, kind,
                                      chunk, k), mode)
                b = drive(make_engine(make_serving_mesh(2), scheme, kind,
                                      chunk, k), mode)
                assert_stream_equal(cell, a, b)
                print('ok', cell)
    """, devices=2, timeout=1200)


def test_allocator_and_prefix_cache_mesh_invariant():
    """Page ids and prefix-cache behavior are head-dimension-free: the
    SAME shared-prefix workload (second request submitted after the first
    drains, so its two full prefix pages hit the published index) on
    (1,1), (1,2) and (2,2) meshes produces identical token streams,
    block-table rows and allocator stats — the scheduler, PageAllocator
    and prefix index never see the device count."""
    _run("""
    shared = [3, 5, 7, 11, 13, 2, 9, 4] * 2       # two full 8-token pages
    def drive_shared(mesh):
        eng = make_engine(mesh, 'fp5.33-e2m3', 'paged_ams', 4)
        eng.submit(shared + [17], max_tokens=4)
        eng.run()                                  # publish prefix pages
        eng.submit(shared + [19], max_tokens=4)    # warm: 2-page prefix hit
        eng.run()
        toks = [list(map(int, r.tokens)) for r in eng.finished]
        return (toks, eng.block_tables.tolist(), eng.alloc.stats())

    base = drive_shared(make_driver_mesh('none'))
    hashes = prefix_page_hashes(np.asarray(shared, np.int32), 8)
    assert base[2]['prefix_hit_pages'] == len(hashes) == 2
    for shape in [(1, 2), (2, 2)]:
        got = drive_shared(make_test_mesh(shape))
        assert got == base, (shape, got, base)
    print('allocator/prefix-cache identical under', [(1,1), (1,2), (2,2)])
    """, devices=8, timeout=600)


def test_no_kv_page_collectives_in_lowered_step():
    """HLO inspection of the compiled tp=2 step: activation all-gathers
    are expected (the bit-exact layout trades one tiny gather per linear
    for never splitting a contraction), but NO collective may touch an
    operand shaped like the page pool — pages are written, truncated and
    attended device-local, never gathered or resharded."""
    _run("""
    import re
    eng = make_engine(make_serving_mesh(2), 'fp5.33-e2m3', 'paged_ams', 4, 2)
    txt = eng._step.lower(*eng._step_shapes.values()).compile().as_text()
    ccfg = eng.cache_cfg
    pagedims = f'{ccfg.num_pages},{ccfg.page_size},'
    coll = [ln for ln in txt.splitlines()
            if re.search(r'all-gather|all-to-all|collective-permute', ln)]
    assert coll, 'expected activation collectives in a tp=2 step'
    bad = [ln for ln in coll if pagedims in ln]
    assert not bad, 'KV pages crossed the mesh:\\n' + '\\n'.join(bad[:4])
    print(f'{len(coll)} collectives, none touching ({ccfg.num_pages}, '
          f'{ccfg.page_size}) pool operands')
    """, devices=2, timeout=600)


def test_per_device_kv_bytes_scale_as_inverse_tp():
    """`kv_bytes_per_token` and the cost-model KV floors are per-device:
    a head-sharded tp=2 pool holds half the bytes per token per device,
    and `kv_floor_ratio` stays 1.0 because achieved and floor divide by
    the same shard count."""
    _run("""
    e1 = make_engine(make_driver_mesh('none'), 'fp5.33-e2m3', 'paged_ams', 4)
    e2 = make_engine(make_serving_mesh(2), 'fp5.33-e2m3', 'paged_ams', 4)
    assert e2.kv_bytes_per_token() * 2 == e1.kv_bytes_per_token()
    for f in ('kv_bytes_per_token', 'kv_ideal_bytes_per_token',
              'kv_bf16_bytes_per_token', 'kv_dequant_bytes_per_token'):
        assert getattr(e2.cost_model, f) * 2 == getattr(e1.cost_model, f), f
    assert e2.cost_model.weight_bytes * 2 == e1.cost_model.weight_bytes
    assert e1.signature['tp'] == 1 and e2.signature['tp'] == 2
    # compression ratio is per-device over per-device: tp-invariant
    assert e1.kv_compression_vs_bf16() == e2.kv_compression_vs_bf16()
    _, st = drive(e2, 'greedy')
    assert abs(st['kv_floor_ratio'] - 1.0) < 1e-9, st['kv_floor_ratio']
    print('per-device kv accounting scales 1/tp; floor ratio', st['kv_floor_ratio'])
    """, devices=2, timeout=600)


# ------------------------------------------------------- host-side (no mesh)
def test_cost_model_kv_shards():
    """`build_cost_model(kv_shards=...)` divides every KV floor (the
    per-device view) and rejects non-divisible head counts."""
    from repro.cache import CacheConfig
    from repro.configs import get_config
    from repro.obs import build_cost_model

    cfg = get_config("qwen2-7b").reduced()
    ccfg = CacheConfig(kind="paged_ams", page_size=8).sized(capacity=32, slots=2)
    full = build_cost_model(cfg, "fp5.33-e2m3", ccfg, kv=2, hd=32)
    half = build_cost_model(cfg, "fp5.33-e2m3", ccfg, kv=2, hd=32, kv_shards=2)
    for f in ("kv_bytes_per_token", "kv_ideal_bytes_per_token",
              "kv_bf16_bytes_per_token", "kv_dequant_bytes_per_token"):
        assert getattr(half, f) * 2 == getattr(full, f), f
    # weight/flop terms are governed by tp, not kv_shards
    assert half.weight_bytes == full.weight_bytes
    assert half.flops_per_token == full.flops_per_token
    with pytest.raises(ValueError):
        build_cost_model(cfg, "fp5.33-e2m3", ccfg, kv=3, hd=32, kv_shards=2)


def test_attn_plan_key_local_heads_and_budget():
    """A plan tuned at one kv-head count / VMEM budget is never served for
    another: both join the autotune key, so tp=1 and tp=4 head slices of
    the same cache shape plan independently."""
    from repro.kernels.tuning import (
        VMEM_BYTES,
        AutotuneCache,
        attn_plan_key,
        plan_attention_tiles,
    )

    kw = dict(kind="contiguous", family="gqa", scheme=None, rows=8, hd=32,
              hd_v=32, s_max=64)
    k_full = attn_plan_key(page=0, kv_heads=8, **kw)
    k_slice = attn_plan_key(page=0, kv_heads=2, **kw)
    k_budget = attn_plan_key(page=0, kv_heads=8, budget=VMEM_BYTES // 2, **kw)
    assert len({k_full, k_slice, k_budget}) == 3
    cache = AutotuneCache()
    plan_attention_tiles(cache=cache, kv_heads=8, **kw)
    assert cache.get(k_full) is not None and cache.get(k_slice) is None
    plan_attention_tiles(cache=cache, kv_heads=2, **kw)
    assert len(cache) == 2                      # distinct entries, no reuse
