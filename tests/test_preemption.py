"""Preemption + host-tier KV spill: the bit-exactness acceptance grid.

The load-bearing property of this subsystem: preempting a running request
— spilling its private KV pages' PACKED content to host memory, giving the
pages away, and resuming later into different physical pages — must be
invisible in the token stream. The grid below forces a mid-stream
preempt/resume across {paged_bf16, paged_ams} x prefill chunk {1, 4} x
{greedy, seeded sampling} and requires the continued stream to be
bit-identical to an uninterrupted run. AMS packed planes (hi/lsb/scale)
are additionally byte-compared across the spill round trip — quantization
happens ONCE at insert, so a spill/restore cycle must move bytes, never
re-quantize.

Below the engine: PageAllocator preempt/resume/host-tier unit tests
(refcount + invariant checks), shared-prefix refcount preservation across
preemption, and the host spill tier serving a prefix hit whose pages were
evicted from the device pool.
"""

import numpy as np
import pytest

import jax

from repro.cache import PageAllocator, extract_pages
from repro.cache.allocator import prefix_page_hashes
from repro.serving import (
    CacheConfig,
    EngineConfig,
    SamplingParams,
    ServeEngine,
)

ARCH = "qwen2-7b"
SCHEME = "fp5.33-e2m3"
GREEDY = None
SEEDED = SamplingParams(temperature=0.8, top_k=16, seed=42)


def config(kind: str, chunk: int, **kw) -> EngineConfig:
    base = dict(arch=ARCH, scheme=SCHEME, slots=2, capacity=48,
                prefill_chunk=chunk,
                cache=CacheConfig(kind=kind, page_size=8,
                                  host_spill_pages=32))
    base.update(kw)
    return EngineConfig(**base)


def tree_equal_bytes(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        x.dtype == y.dtype and np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# =========================================================== acceptance grid
@pytest.mark.parametrize("kind", ["paged_bf16", "paged_ams"])
@pytest.mark.parametrize("chunk", [1, 4])
@pytest.mark.parametrize("sampling", [GREEDY, SEEDED],
                         ids=["greedy", "seeded"])
def test_preempt_resume_stream_bit_identical(kind, chunk, sampling):
    """Force a preemption mid-decode and one mid-prefill: every continued
    stream must match the uninterrupted reference bit-for-bit."""
    prompt = (np.arange(1, 14, dtype=np.int32) * 3) % 200 + 1
    ec = config(kind, chunk)
    ref = ServeEngine(ec).submit(prompt, max_tokens=10,
                                 sampling=sampling).result()

    prefill_ticks = -(-len(prompt) // chunk)
    for ticks_before in (2, prefill_ticks + 3):   # mid-prefill, mid-decode
        eng = ServeEngine(ec)
        h = eng.submit(prompt, max_tokens=10, sampling=sampling)
        for _ in range(ticks_before):
            eng.step()
        assert h.status in ("prefill", "decode")
        eng.preempt(h.request.slot)
        assert h.status == "preempted"
        assert h.request.spill is not None
        out = h.result()
        assert h.status == "finished"
        assert out == ref, (
            f"{kind}/chunk{chunk}: stream diverged after preempt at "
            f"tick {ticks_before}")
        s = eng.stats()
        assert s["preemptions"] == 1 and s["resumes"] == 1


def test_ams_planes_byte_exact_across_spill_round_trip():
    """The spilled AMS planes (packed hi/lsb/scale) must land back in the
    pool byte-identical — spill moves bytes, it never re-quantizes."""
    eng = ServeEngine(config("paged_ams", 1))
    h = eng.submit((np.arange(1, 20, dtype=np.int32) * 7) % 300 + 1,
                   max_tokens=8)
    # stop on a PAGE BOUNDARY (fed == 8 == page_size): the spilled page is
    # complete, so post-resume inserts land in LATER pages and the restored
    # page must stay byte-frozen through the rest of the stream
    for _ in range(8):
        eng.step()
    req = h.request
    eng.preempt(req.slot)
    sp = req.spill
    assert sp.fed == 8 and sp.n_pages == 1 and sp.nbytes > 0
    spilled = jax.tree.map(np.copy, sp.content)
    n_keep = sp.n_keep
    # churn another request through the freed pages while h resumes
    other = eng.submit(np.arange(50, 71, dtype=np.int32), max_tokens=4)
    assert h.result() is not None and other.result() is not None
    assert req.spill is None and h.status == "finished"
    restored = extract_pages(
        eng.cache, req.pages[n_keep:n_keep + sp.n_pages])
    assert tree_equal_bytes(spilled, restored), (
        "AMS packed planes changed across the spill round trip")


# ======================================================== priority policy e2e
def test_priority_preemption_end_to_end():
    """Two low-priority requests saturate both slots; a high-priority
    arrival must preempt one (latest admitted), run to completion first,
    and every stream — including the victim's — must match its solo run."""
    ec = config("paged_ams", 1)
    long_p = np.arange(1, 11, dtype=np.int32)
    short_p = np.arange(100, 105, dtype=np.int32)
    refs = [ServeEngine(ec).submit(p, max_tokens=m).result()
            for p, m in ((long_p, 16), (long_p + 1, 16), (short_p, 4))]

    eng = ServeEngine(ec)
    h0 = eng.submit(long_p, max_tokens=16, priority=0)
    eng.step()          # stagger admit ticks: h1 is the LATER victim
    h1 = eng.submit(long_p + 1, max_tokens=16, priority=0)
    for _ in range(5):
        eng.step()
    hi = eng.submit(short_p, max_tokens=4, priority=5)
    eng.step()
    assert eng.preemptions == 1
    assert hi.status in ("prefill", "decode")     # admitted immediately
    victim = h1 if h1.status == "preempted" else h0
    assert victim is h1, "policy must evict the LATEST-admitted victim"
    out_hi = hi.result()
    assert victim.request.preemptions == 1
    outs = [h0.result(), h1.result(), out_hi]
    assert outs == refs, "priority moved WHEN requests run, never WHAT"
    assert hi.request.finish_tick < victim.request.finish_tick


def test_equal_priority_never_preempts():
    """Strictness: an equal-priority head waits (head-of-line FIFO, the
    PR 1-9 behaviour) — no ping-pong between peers."""
    ec = config("paged_ams", 1)
    eng = ServeEngine(ec)
    eng.submit(np.arange(1, 8, dtype=np.int32), max_tokens=12)
    eng.submit(np.arange(2, 9, dtype=np.int32), max_tokens=12)
    for _ in range(3):
        eng.step()
    h = eng.submit(np.arange(3, 10, dtype=np.int32), max_tokens=4)
    eng.run()
    assert eng.preemptions == 0
    assert h.done


# =========================================================== shared prefixes
def test_shared_prefix_pages_survive_preemption():
    """Preemption releases only PRIVATE pages: a victim sharing prefix
    pages with a live request keeps them pinned (no spill, no refcount
    drop below the co-owner), and resume never re-prefills them."""
    ec = config("paged_ams", 1)
    sys_prompt = np.arange(200, 216, dtype=np.int32)      # two full pages
    mk = lambda tail: np.concatenate([sys_prompt, tail])
    a_p, b_p = mk(np.arange(1, 6, dtype=np.int32)), \
        mk(np.arange(50, 54, dtype=np.int32))
    refs = [ServeEngine(ec).submit(p, max_tokens=8).result()
            for p in (a_p, b_p)]

    eng = ServeEngine(ec)
    ha = eng.submit(a_p, max_tokens=8)
    while ha.request.published < 2:       # shared pages live in the index
        eng.step()
    hb = eng.submit(b_p, max_tokens=8)
    while hb.status == "queued":
        eng.step()
    assert hb.request.cached_len == 16    # prefix served from shared pages
    shared = list(hb.request.pages[:2])
    eng.preempt(hb.request.slot)
    # the victim's KEPT prefix must still be pinned for it
    assert hb.request.pages == shared
    assert hb.request.spill.n_keep == 2
    for p in shared:
        assert eng.alloc.refcount(p) >= 1
    outs = [ha.result(), hb.result()]
    assert outs == refs
    eng.alloc.check_invariants()
    assert eng.stats()["cached_token_frac"] > 0


# ============================================================ host spill tier
def test_host_tier_serves_evicted_prefix():
    """Prefix pages evicted from the device pool under pressure spill to
    the host tier and come back on a later prefix match — the restored
    request streams identically and the tier counters move."""
    cache = CacheConfig(kind="paged_ams", page_size=8, host_spill_pages=16)
    ec = EngineConfig(arch=ARCH, scheme=SCHEME, slots=1, capacity=32,
                      cache=cache)
    prompt = np.arange(300, 317, dtype=np.int32)          # two full pages
    ref = ServeEngine(ec).submit(prompt, max_tokens=6).result()

    eng = ServeEngine(ec)
    assert eng.submit(prompt, max_tokens=6).result() == ref
    # pool is slots*capacity/page_size = 4 pages; churn DISTINCT prompts
    # through it so the published prefix pages get evicted (and spilled)
    for j in range(3):
        eng.submit(np.arange(1 + 40 * j, 18 + 40 * j, dtype=np.int32),
                   max_tokens=6).result()
    assert eng.alloc.host_spills >= 2, "prefix pages never reached the tier"
    h = eng.submit(prompt, max_tokens=6)
    out = h.result()
    assert out == ref
    assert eng.alloc.host_restores >= 2
    assert h.request.cached_len >= 16     # the hit came from the tier
    eng.alloc.check_invariants()
    s = eng.alloc.stats()
    assert s["host_spill_pages_total"] >= 2
    assert s["host_restore_pages_total"] >= 2


# ========================================================== allocator (unit)
class TestAllocatorPreemptResume:
    def _alloc(self, n=8, host=0):
        return PageAllocator(n, page_size=4, host_spill_pages=host)

    def test_preempt_releases_private_keeps_order(self):
        a = self._alloc()
        pages, _ = a.alloc(1, 4, [])
        released = a.preempt(1, 1)
        assert released == pages[1:]
        assert a.free_pages == 7          # 3 back, 1 still pinned
        assert a.can_resume(1, 4)
        new = a.resume(1, 4)
        assert len(new) == 3 and set(new).isdisjoint({pages[0]})
        a.free(1)
        assert a.free_pages == 8
        a.check_invariants()

    def test_preempt_keeps_shared_refcounts(self):
        a = self._alloc()
        h = prefix_page_hashes(np.arange(8, dtype=np.int32), 4, "k")
        p1, _ = a.alloc(1, 3, h)
        for j in range(2):
            a.publish(1, h[j], p1[j])
        p2, matched = a.alloc(2, 3, h)
        assert matched == 2 and p2[:2] == p1[:2]
        a.preempt(2, 2)                   # rid 2 keeps the shared prefix
        assert a.refcount(p1[0]) == 2 and a.refcount(p1[1]) == 2
        a.resume(2, 3)
        a.free(1)
        assert a.refcount(p1[0]) == 1     # rid 2 still pins it
        a.check_invariants()

    def test_host_tier_spill_and_restore(self):
        a = self._alloc(n=4, host=8)
        store = {}
        a.spill_fn = lambda page: store.setdefault(page, f"content-{page}")
        h = prefix_page_hashes(np.arange(8, dtype=np.int32), 4, "k")
        p1, _ = a.alloc(1, 2, h)
        for j in range(2):
            a.publish(1, h[j], p1[j])
        a.free(1)                          # both pages now LRU-evictable
        a.alloc(2, 4, [])                  # full pool: evicts + spills both
        assert a.host_spills == 2
        a.free(2)
        p3, matched = a.alloc(3, 2, h)     # host hit: fresh pages + restore
        assert matched == 2
        assert sorted(p for p, _ in a.pending_restores) == sorted(p3)
        assert {c for _, c in a.pending_restores} == set(store.values())
        a.pending_restores.clear()
        a.check_invariants()

    def test_tier_capacity_evicts_oldest(self):
        a = self._alloc(n=2, host=1)
        spilled = []
        a.spill_fn = lambda page: spilled.append(page) or f"c{page}"
        h = prefix_page_hashes(np.arange(8, dtype=np.int32), 4, "k")
        p1, _ = a.alloc(1, 2, h)
        for j in range(2):
            a.publish(1, h[j], p1[j])
        a.free(1)
        a.alloc(2, 2, [])                  # spills both, tier holds ONE
        assert a.host_spills == 2
        assert a.stats()["pages_host_tier"] == 1
        a.check_invariants()
