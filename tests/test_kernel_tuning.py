"""Tile planning: every plan must fit VMEM, align to packing + MXU, and the
planned tiles must produce correct results through the kernel. The fused
attention template's planner adds a persistent per-(shape, family, scheme)
autotune cache — its contract (deterministic default, VMEM-budget
rejection, JSON round-trip, measured selection behind an explicit
callable) is pinned below."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SCHEMES, get_scheme, quantize_linear
from repro.core.packing import make_layout
from repro.kernels import ops, ref
from repro.kernels.tuning import (
    VMEM_BYTES,
    AttnTilePlan,
    AutotuneCache,
    attn_plan_key,
    attn_vmem_usage,
    plan_attention_tiles,
    plan_tiles,
    vmem_usage,
)


@pytest.mark.parametrize("scheme", list(SCHEMES))
@pytest.mark.parametrize("K,N,B", [(4096, 4096, 8), (18944, 3584, 1),
                                   (896, 151936, 64)])
def test_plans_fit_and_align(scheme, K, N, B):
    lay = make_layout(SCHEMES[scheme])
    plan = plan_tiles(lay, B, K, N)
    assert plan.vmem_bytes <= VMEM_BYTES
    assert plan.bk % lay.k_block == 0
    assert plan.bk % 128 == 0
    assert plan.bn % 128 == 0
    # claimed usage formula is self-consistent
    assert plan.vmem_bytes == vmem_usage(lay, plan.bb, plan.bk, plan.bn)


def test_ams_matmul_defaults_come_from_plan_and_fit_vmem():
    """ops.ams_matmul with no block overrides must select its tiles via
    plan_tiles (the VMEM-budgeted plan), stay under budget for every
    production-ish shape, and still compute correctly — plan_tiles was
    previously dead code next to hardcoded block_b=8/block_n=256."""
    s = get_scheme("fp5.33-e2m3")
    lay = make_layout(s)
    for K, N, B in [(1536, 512, 4), (4096, 4096, 8), (896, 2048, 64)]:
        plan = plan_tiles(lay, B, K, N)
        assert plan.vmem_bytes <= VMEM_BYTES, (K, N, B)
    # correctness through the kernel with the planned defaults
    K, N, B = 1536, 512, 4
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32) * 0.02)
    x = jnp.asarray(rng.standard_normal((B, K)).astype(np.float32))
    q = quantize_linear(w, s)
    assert ops.default_tiles(q.packed, B) == plan_tiles(lay, B, K, N)
    y = ops.ams_matmul(x, q.packed, interpret=True)   # no explicit blocks
    xb = x.astype(jnp.bfloat16).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.ams_matmul_ref(xb, q.packed)),
                               rtol=1e-5, atol=1e-5)
    # explicit overrides still win over the plan
    y2 = ops.ams_matmul(x, q.packed, interpret=True, block_b=8, block_n=128)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y),
                               rtol=1e-5, atol=1e-5)


def test_planned_tiles_run_correctly():
    s = get_scheme("fp5.33-e2m3")
    lay = make_layout(s)
    K, N, B = 1536, 512, 4
    plan = plan_tiles(lay, B, K, N)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32) * 0.02)
    x = jnp.asarray(rng.standard_normal((B, K)).astype(np.float32))
    q = quantize_linear(w, s)
    y = ops.ams_matmul(x, q.packed, interpret=True, block_b=plan.bb,
                       block_k=plan.bk, block_n=plan.bn)
    xb = x.astype(jnp.bfloat16).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.ams_matmul_ref(xb, q.packed)),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------- fused-attention autotune cache
def test_attn_plan_deterministic_default():
    """No measure callable -> the plan is a pure function of the shape: the
    LARGEST divisor of s_max whose working set fits the budget. Two fresh
    caches must agree exactly (CI reproducibility)."""
    kw = dict(kind="contiguous", family="gqa", scheme=None, rows=8, hd=32,
              hd_v=32, s_max=48)
    a = plan_attention_tiles(cache=AutotuneCache(), **kw)
    b = plan_attention_tiles(cache=AutotuneCache(), **kw)
    assert a == b and a.source == "default"
    assert 48 % a.block_kv == 0
    assert a.vmem_bytes == attn_vmem_usage(8, a.block_kv, 32, 32, None)
    assert a.vmem_bytes <= VMEM_BYTES
    # every larger divisor must have been rejected for VMEM, not skipped
    for bk in (d for d in range(a.block_kv + 1, 49) if 48 % d == 0):
        assert attn_vmem_usage(8, bk, 32, 32, None) > VMEM_BYTES


def test_attn_plan_vmem_budget_rejection():
    """Shrinking the budget shrinks the block; a budget nothing fits falls
    back to the smallest divisor and says so in ``source``."""
    kw = dict(kind="contiguous", family="gqa", scheme=None, rows=8, hd=32,
              hd_v=32, s_max=64)
    big = plan_attention_tiles(cache=AutotuneCache(), **kw)
    tight = attn_vmem_usage(8, big.block_kv, 32, 32, None) - 1
    small = plan_attention_tiles(cache=AutotuneCache(), budget=tight, **kw)
    assert small.block_kv < big.block_kv
    assert small.vmem_bytes <= tight
    none_fit = plan_attention_tiles(cache=AutotuneCache(), budget=1, **kw)
    assert none_fit.block_kv == 1 and none_fit.source == "fallback"


def test_attn_plan_paged_kind_is_the_page():
    plan = plan_attention_tiles(kind="paged", family="gqa", scheme="fp4.25-e2m2",
                                rows=4, hd=32, s_max=16, page=4,
                                cache=AutotuneCache())
    assert plan.block_kv == 4
    assert plan.vmem_bytes == attn_vmem_usage(4, 4, 32, 32, "fp4.25-e2m2")
    # packed planes stream fewer bytes than the bf16 pair at the same block
    assert (attn_vmem_usage(4, 4, 32, 32, "fp4.25-e2m2")
            < attn_vmem_usage(4, 4, 32, 32, None) + 4 * 4 * 64)


def test_attn_plan_persistence_round_trip(tmp_path):
    """Plans survive the JSON file bit-for-bit, ``source`` included, and a
    fresh process (fresh AutotuneCache on the same path) serves the stored
    plan as a hit instead of re-planning."""
    path = str(tmp_path / "attn_cache.json")
    kw = dict(kind="contiguous", family="mla", scheme=None, rows=16, hd=64,
              hd_v=16, s_max=32)
    cache = AutotuneCache(path)
    plan = plan_attention_tiles(cache=cache, **kw)
    assert len(cache) == 1
    reloaded = AutotuneCache(path)
    key = attn_plan_key(page=0, **kw)
    assert reloaded.get(key) == plan          # exact dataclass round-trip
    # a poisoned stored plan is SERVED, proving the hit path is used
    forged = AttnTilePlan(block_kv=1, rows=16, vmem_bytes=7, source="measured")
    reloaded.put(key, forged)
    assert plan_attention_tiles(cache=AutotuneCache(path), **kw) == forged


def test_attn_plan_measured_selection_and_hit_skips_measure(tmp_path):
    """A measure callable re-ranks the fitting candidates by wall-clock
    (here: rigged to prefer block 4); the winner persists as
    ``source="measured"`` and later measured lookups return the hit
    WITHOUT calling measure again."""
    path = str(tmp_path / "attn_cache.json")
    calls = []

    def rigged(plan):
        calls.append(plan.block_kv)
        return abs(plan.block_kv - 4) + 1.0

    kw = dict(kind="contiguous", family="gqa", scheme=None, rows=8, hd=32,
              hd_v=32, s_max=16)
    plan = plan_attention_tiles(cache=AutotuneCache(path), measure=rigged, **kw)
    assert plan.block_kv == 4 and plan.source == "measured"
    assert sorted(calls) == [1, 2, 4, 8, 16]   # every divisor of 16 timed
    n = len(calls)
    again = plan_attention_tiles(cache=AutotuneCache(path), measure=rigged,
                                 **kw)
    assert again == plan and len(calls) == n   # cache hit: no re-timing
    # an unmeasured (default) hit does NOT satisfy a measured request
    kw2 = dict(kw, family="mla")
    c2 = AutotuneCache()
    d2 = plan_attention_tiles(cache=c2, **kw2)
    assert d2.source == "default"
    m2 = plan_attention_tiles(cache=c2, measure=rigged, **kw2)
    assert m2.source == "measured" and m2.block_kv == 4
