"""Tile planning: every plan must fit VMEM, align to packing + MXU, and the
planned tiles must produce correct results through the kernel."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SCHEMES, get_scheme, quantize_linear
from repro.core.packing import make_layout
from repro.kernels import ops, ref
from repro.kernels.tuning import VMEM_BYTES, plan_tiles, vmem_usage


@pytest.mark.parametrize("scheme", list(SCHEMES))
@pytest.mark.parametrize("K,N,B", [(4096, 4096, 8), (18944, 3584, 1),
                                   (896, 151936, 64)])
def test_plans_fit_and_align(scheme, K, N, B):
    lay = make_layout(SCHEMES[scheme])
    plan = plan_tiles(lay, B, K, N)
    assert plan.vmem_bytes <= VMEM_BYTES
    assert plan.bk % lay.k_block == 0
    assert plan.bk % 128 == 0
    assert plan.bn % 128 == 0
    # claimed usage formula is self-consistent
    assert plan.vmem_bytes == vmem_usage(lay, plan.bb, plan.bk, plan.bn)


def test_ams_matmul_defaults_come_from_plan_and_fit_vmem():
    """ops.ams_matmul with no block overrides must select its tiles via
    plan_tiles (the VMEM-budgeted plan), stay under budget for every
    production-ish shape, and still compute correctly — plan_tiles was
    previously dead code next to hardcoded block_b=8/block_n=256."""
    s = get_scheme("fp5.33-e2m3")
    lay = make_layout(s)
    for K, N, B in [(1536, 512, 4), (4096, 4096, 8), (896, 2048, 64)]:
        plan = plan_tiles(lay, B, K, N)
        assert plan.vmem_bytes <= VMEM_BYTES, (K, N, B)
    # correctness through the kernel with the planned defaults
    K, N, B = 1536, 512, 4
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32) * 0.02)
    x = jnp.asarray(rng.standard_normal((B, K)).astype(np.float32))
    q = quantize_linear(w, s)
    assert ops.default_tiles(q.packed, B) == plan_tiles(lay, B, K, N)
    y = ops.ams_matmul(x, q.packed, interpret=True)   # no explicit blocks
    xb = x.astype(jnp.bfloat16).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.ams_matmul_ref(xb, q.packed)),
                               rtol=1e-5, atol=1e-5)
    # explicit overrides still win over the plan
    y2 = ops.ams_matmul(x, q.packed, interpret=True, block_b=8, block_n=128)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y),
                               rtol=1e-5, atol=1e-5)


def test_planned_tiles_run_correctly():
    s = get_scheme("fp5.33-e2m3")
    lay = make_layout(s)
    K, N, B = 1536, 512, 4
    plan = plan_tiles(lay, B, K, N)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32) * 0.02)
    x = jnp.asarray(rng.standard_normal((B, K)).astype(np.float32))
    q = quantize_linear(w, s)
    y = ops.ams_matmul(x, q.packed, interpret=True, block_b=plan.bb,
                       block_k=plan.bk, block_n=plan.bn)
    xb = x.astype(jnp.bfloat16).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.ams_matmul_ref(xb, q.packed)),
                               rtol=1e-5, atol=1e-5)
