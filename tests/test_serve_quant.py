"""Serving-path fidelity: AMS-quantized model outputs track fp16 outputs.

Uses logit cosine similarity on reduced models (random init — absolute CE
is meaningless, directional fidelity is what PTQ must preserve). The paper's
ordering must hold: more effective bits -> higher fidelity, and fp5.33 must
be close to fp6.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import QuantPolicy
from repro.models import forward_seq, init_params
from repro.models.common import quantize_params


def cos(a, b):
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    return float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-30))


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-7b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)), jnp.int32)
    ref_logits, _, _ = forward_seq(params, tokens, cfg, dtype=jnp.float32,
                                   remat=False)
    return cfg, params, tokens, ref_logits


def logits_for(cfg, params, tokens, scheme, strategy="set_lsb", impl="ref"):
    pol = QuantPolicy(scheme=scheme, strategy=strategy, impl=impl,
                      min_elements=1 << 10)
    qp = quantize_params(params, pol)
    out, _, _ = forward_seq(qp, tokens, cfg, policy=pol, dtype=jnp.float32,
                            remat=False)
    return out


def test_fidelity_ordering(setup):
    cfg, params, tokens, ref = setup
    sims = {}
    for scheme in ("fp6-e2m3", "fp5.33-e2m3", "fp5-e2m2", "fp4.25-e2m2",
                   "fp4-e2m1"):
        sims[scheme] = cos(logits_for(cfg, params, tokens, scheme), ref)
    assert sims["fp6-e2m3"] > 0.99
    assert sims["fp5.33-e2m3"] > 0.98
    assert sims["fp5.33-e2m3"] >= sims["fp4-e2m1"]
    assert sims["fp4.25-e2m2"] >= sims["fp4-e2m1"] - 1e-3
    # the paper's headline: fp5.33 ~ fp6
    assert sims["fp6-e2m3"] - sims["fp5.33-e2m3"] < 0.015, sims


def test_requantize_at_least_as_faithful(setup):
    cfg, params, tokens, ref = setup
    s_set = cos(logits_for(cfg, params, tokens, "fp4.25-e2m2", "set_lsb"), ref)
    s_rq = cos(logits_for(cfg, params, tokens, "fp4.25-e2m2", "requantize"), ref)
    assert s_rq >= s_set - 5e-4, (s_set, s_rq)


def test_impls_agree(setup):
    cfg, params, tokens, _ = setup
    a = logits_for(cfg, params, tokens, "fp5.33-e2m3", impl="ref")
    b = logits_for(cfg, params, tokens, "fp5.33-e2m3", impl="fused_ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-2,
                               atol=2e-2)
    assert cos(a, b) > 0.9999
