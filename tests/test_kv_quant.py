"""AMS-KV (beyond-paper): quantized KV cache numerics + attention fidelity.

Edge-case coverage (jit, non-multiple-of-k head dims, degenerate token
axes) pins exactly the shapes the paged KV-cache kernel feeds through
`quantize_kv` at insert time (see repro.cache.pool)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_scheme
from repro.core.kv_quant import (
    dequantize_kv,
    kv_bytes,
    packed_head_dim,
    quantize_kv,
)
from repro.models.attention import flash_decode, kv_index_map


def rand_kv(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)


@pytest.mark.parametrize("hd", [64, 128, 256])
def test_roundtrip_error_bounded(hd):
    x = rand_kv((4, 16, 2, hd), seed=hd)
    q = quantize_kv(x)
    y = dequantize_kv(q, hd, dtype=jnp.float32)
    assert y.shape == x.shape
    # theoretical worst case: the shared-LSB sub-lattice gap at the top of
    # the e2m2 range is 2/7.5 ~= 0.267 relative to the per-vector amax
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    rel = np.asarray(jnp.abs(y - x) / jnp.maximum(amax, 1e-9))
    assert rel.max() <= 2 / 7.5 + 1e-6, rel.max()
    assert np.asarray(jnp.abs(y - x)).mean() < 0.06 * float(amax.mean())


def test_compression_ratio():
    packed, bf16 = kv_bytes(128)
    assert packed == 64 + 4 + 4  # nibbles + 1 lsb word + scale
    assert bf16 / packed > 3.5


def test_roundtrip_under_jit():
    """quantize/dequantize round-trips inside jax.jit with identical planes
    and values — the paged engine runs it inside the jitted decode step."""
    x = rand_kv((3, 5, 2, 64), seed=11)

    @jax.jit
    def roundtrip(x):
        q = quantize_kv(x)
        return q, dequantize_kv(q, x.shape[-1], dtype=jnp.float32)

    q_j, y_j = roundtrip(x)
    q_e = quantize_kv(x)
    y_e = dequantize_kv(q_e, 64, dtype=jnp.float32)
    # codes must agree bit-for-bit; the f32 scale may differ in the last ulp
    # (XLA fuses the amax/max_normal divide differently under jit)
    for pl in ("hi", "lsb"):
        np.testing.assert_array_equal(np.asarray(q_j[pl]), np.asarray(q_e[pl]))
    np.testing.assert_allclose(np.asarray(q_j["scale"]),
                               np.asarray(q_e["scale"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(y_j), np.asarray(y_e), atol=1e-6)


@pytest.mark.parametrize("hd", [90, 33, 6])
def test_head_dim_not_multiple_of_k(hd):
    """hd % k != 0 (and odd hd): planes pad to packed_head_dim, dequantize
    slices the pad off, and the error bound still holds."""
    k = get_scheme("fp4.25-e2m2").k
    hd_p = packed_head_dim(hd)
    assert hd_p % k == 0 and hd_p % 2 == 0 and hd_p >= hd
    x = rand_kv((4, 3, 2, hd), seed=hd)
    q = quantize_kv(x)
    assert q["hi"].shape[-1] == hd_p // 2
    y = dequantize_kv(q, hd, dtype=jnp.float32)
    assert y.shape == x.shape
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    rel = np.asarray(jnp.abs(y - x) / jnp.maximum(amax, 1e-9))
    assert rel.max() <= 2 / 7.5 + 1e-6, rel.max()


@pytest.mark.parametrize("n_tok", [0, 1])
def test_degenerate_token_axes(n_tok):
    """Zero-length and singleton token axes round-trip with the right
    shapes (a paged engine tick can quantize a batch with no active slots)."""
    x = rand_kv((2, n_tok, 2, 32), seed=21)
    q = quantize_kv(x)
    assert q["hi"].shape == (2, n_tok, 2, 16)
    assert q["scale"].shape == (2, n_tok, 2, 1)
    y = dequantize_kv(q, 32, dtype=jnp.float32)
    assert y.shape == x.shape
    if n_tok:
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        rel = np.asarray(jnp.abs(y - x) / jnp.maximum(amax, 1e-9))
        assert rel.max() <= 2 / 7.5 + 1e-6


def test_adaptive_beats_forced_on_kv():
    x = rand_kv((8, 8, 1, 128), seed=3)
    s = get_scheme("fp4.25-e2m2")
    q_ad = dequantize_kv(quantize_kv(x, s, "set_lsb"), 128, dtype=jnp.float32)
    q_rq = dequantize_kv(quantize_kv(x, s, "requantize"), 128, dtype=jnp.float32)
    mse_ad = float(jnp.mean((q_ad - x) ** 2))
    mse_rq = float(jnp.mean((q_rq - x) ** 2))
    assert mse_rq <= mse_ad + 1e-12


def test_attention_through_quantized_cache():
    """flash_decode on a dequantized AMS-KV cache tracks the fp cache."""
    B, S, KV, HD, H = 2, 64, 2, 128, 8
    k_cache = rand_kv((B, S, KV, HD), seed=5, scale=0.5)
    v_cache = rand_kv((B, S, KV, HD), seed=6, scale=0.5)
    q = rand_kv((B, H, HD), seed=7)
    kvm = kv_index_map(H, H, KV)
    pos = jnp.int32(50)

    o_ref = flash_decode(q, k_cache, v_cache, pos, kv_map=kvm)
    kq = dequantize_kv(quantize_kv(k_cache), HD, dtype=jnp.float32)
    vq = dequantize_kv(quantize_kv(v_cache), HD, dtype=jnp.float32)
    o_q = flash_decode(q, kq, vq, pos, kv_map=kvm)

    cos = float(jnp.sum(o_ref * o_q) /
                (jnp.linalg.norm(o_ref) * jnp.linalg.norm(o_q) + 1e-30))
    assert cos > 0.99, cos
    assert float(jnp.max(jnp.abs(o_ref - o_q))) < 0.15
