"""AMS-KV (beyond-paper): quantized KV cache numerics + attention fidelity."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_scheme
from repro.core.kv_quant import dequantize_kv, kv_bytes, quantize_kv
from repro.models.attention import flash_decode, kv_index_map


def rand_kv(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)


@pytest.mark.parametrize("hd", [64, 128, 256])
def test_roundtrip_error_bounded(hd):
    x = rand_kv((4, 16, 2, hd), seed=hd)
    q = quantize_kv(x)
    y = dequantize_kv(q, hd, dtype=jnp.float32)
    assert y.shape == x.shape
    # theoretical worst case: the shared-LSB sub-lattice gap at the top of
    # the e2m2 range is 2/7.5 ~= 0.267 relative to the per-vector amax
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    rel = np.asarray(jnp.abs(y - x) / jnp.maximum(amax, 1e-9))
    assert rel.max() <= 2 / 7.5 + 1e-6, rel.max()
    assert np.asarray(jnp.abs(y - x)).mean() < 0.06 * float(amax.mean())


def test_compression_ratio():
    packed, bf16 = kv_bytes(128)
    assert packed == 64 + 4 + 4  # nibbles + 1 lsb word + scale
    assert bf16 / packed > 3.5


def test_adaptive_beats_forced_on_kv():
    x = rand_kv((8, 8, 1, 128), seed=3)
    s = get_scheme("fp4.25-e2m2")
    q_ad = dequantize_kv(quantize_kv(x, s, "set_lsb"), 128, dtype=jnp.float32)
    q_rq = dequantize_kv(quantize_kv(x, s, "requantize"), 128, dtype=jnp.float32)
    mse_ad = float(jnp.mean((q_ad - x) ** 2))
    mse_rq = float(jnp.mean((q_rq - x) ** 2))
    assert mse_rq <= mse_ad + 1e-12


def test_attention_through_quantized_cache():
    """flash_decode on a dequantized AMS-KV cache tracks the fp cache."""
    B, S, KV, HD, H = 2, 64, 2, 128, 8
    k_cache = rand_kv((B, S, KV, HD), seed=5, scale=0.5)
    v_cache = rand_kv((B, S, KV, HD), seed=6, scale=0.5)
    q = rand_kv((B, H, HD), seed=7)
    kvm = kv_index_map(H, H, KV)
    pos = jnp.int32(50)

    o_ref = flash_decode(q, k_cache, v_cache, pos, kv_map=kvm)
    kq = dequantize_kv(quantize_kv(k_cache), HD, dtype=jnp.float32)
    vq = dequantize_kv(quantize_kv(v_cache), HD, dtype=jnp.float32)
    o_q = flash_decode(q, kq, vq, pos, kv_map=kvm)

    cos = float(jnp.sum(o_ref * o_q) /
                (jnp.linalg.norm(o_ref) * jnp.linalg.norm(o_q) + 1e-30))
    assert cos > 0.99, cos
    assert float(jnp.max(jnp.abs(o_ref - o_q))) < 0.15
