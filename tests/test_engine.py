"""Continuous-batching engine: scheduling correctness + batch invariance.

The load-bearing property: a request's greedy token stream is IDENTICAL
whether it runs alone through one-shot ``generate`` or packed against
arbitrary neighbours mid-stream in the engine (attention hard-masks invalid
cache positions to exact zeros, and every slot's math is row-independent).
Plus: slot reuse after completion (including recurrent-state reset) and
capacity-full FIFO queuing.
"""

import numpy as np
import pytest

from repro.launch.serve import generate
from repro.serving import EngineConfig, ServeEngine

ARCH = "qwen2-7b"
SCHEME = "fp5.33-e2m3"
CAP = 32


def one_shot(prompt, max_tokens, arch=ARCH, scheme=SCHEME):
    toks, _ = generate(arch, scheme=scheme, batch=1,
                       prompt_len=len(prompt), gen_tokens=max_tokens,
                       seed=0, prompts=np.asarray(prompt)[None], capacity=CAP)
    return toks[0]


@pytest.fixture(scope="module")
def mixed_requests():
    rng = np.random.default_rng(1)
    lens, maxtok = (5, 9, 13), (8, 6, 10)
    return [rng.integers(0, 512, n) for n in lens], maxtok


def test_continuous_matches_one_shot(mixed_requests):
    """3 concurrent requests, different lengths AND arrival ticks, on 2 slots
    (the third queues) — exact match against per-request one-shot decoding."""
    prompts, maxtok = mixed_requests
    eng = ServeEngine(EngineConfig(arch=ARCH, scheme=SCHEME, slots=2, capacity=CAP))
    arrivals = {0: [0], 2: [1], 7: [2]}
    reqs, tick = [], 0
    while eng.has_work or tick <= max(arrivals):
        for j in arrivals.get(tick, []):
            reqs.append(eng.submit(prompts[j], maxtok[j]))
        eng.step()
        tick += 1

    assert all(r.done for r in reqs)
    # the Request contract is [P] int32 end to end (engine, scheduler, steps)
    assert all(r.prompt.dtype == np.int32 for r in reqs)
    for j, r in enumerate(reqs):
        expect = one_shot(prompts[j], maxtok[j])
        np.testing.assert_array_equal(
            np.asarray(r.tokens), expect,
            err_msg=f"request {j} diverged from one-shot decode")


def test_slot_reuse_after_completion(mixed_requests):
    """One slot, three queued requests: each admission reuses the slot and
    must be bit-identical to a fresh solo run (stale cache fully isolated)."""
    prompts, maxtok = mixed_requests
    eng = ServeEngine(EngineConfig(arch=ARCH, scheme=SCHEME, slots=1, capacity=CAP))
    reqs = [eng.submit(p, m) for p, m in zip(prompts, maxtok)]
    eng.run()

    admits = [r.admit_tick for r in reqs]
    assert admits == sorted(admits) and len(set(admits)) == 3, admits
    assert all(r.slot == 0 for r in reqs)
    for j, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.tokens),
                                      one_shot(prompts[j], maxtok[j]))


def test_capacity_full_queuing():
    """More requests than slots: the overflow queues (FIFO) and admission
    happens only as slots free up; everything eventually completes."""
    rng = np.random.default_rng(3)
    eng = ServeEngine(EngineConfig(arch=ARCH, scheme=SCHEME, slots=2, capacity=CAP))
    reqs = [eng.submit(rng.integers(0, 512, 4 + j), 4) for j in range(4)]
    assert eng.sched.queue_depth == 4
    eng.step()
    # both slots filled, two requests still waiting
    assert eng.active_count == 2
    assert eng.sched.queue_depth == 2
    assert [r.admit_tick for r in reqs[:2]] == [0, 0]
    assert reqs[2].admit_tick == -1 and reqs[3].admit_tick == -1

    eng.run()
    assert all(r.done for r in reqs)
    assert eng.sched.queue_depth == 0
    # FIFO: later submissions never admitted before earlier ones
    assert reqs[2].admit_tick <= reqs[3].admit_tick
    assert all(len(r.tokens) == 4 for r in reqs)


def test_submit_rejects_oversized():
    eng = ServeEngine(EngineConfig(arch=ARCH, scheme=SCHEME, slots=1, capacity=16))
    with pytest.raises(ValueError, match="cache positions"):
        eng.submit(np.arange(10), max_tokens=10)  # needs 19 > 16
    with pytest.raises(ValueError):
        eng.submit(np.arange(0), max_tokens=4)    # empty prompt


def test_generate_wrapper_shapes():
    toks, stats = generate(ARCH, scheme=SCHEME, batch=2, prompt_len=6,
                           gen_tokens=5, seed=0)
    assert toks.shape == (2, 5)
    assert stats["requests_finished"] == 2
    assert stats["tokens_generated"] == 10
    assert "decode_ms_median" in stats and "tokens_per_s" in stats
