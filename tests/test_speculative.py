"""Speculative decoding through the ragged engine step (`launch.speculative`).

The invariants that make speculation verifiable rather than asserted:

  (a) temperature=0 speculative streams are BIT-IDENTICAL to the
      non-speculative streams of the SAME cache mode, across
      {contiguous, paged_bf16, paged_ams} × chunk {1, 4} × both drafters
      × k ∈ {1, 2, 4} — speculation changes how many tokens emerge per
      step, never which tokens (comparisons are within one cache mode:
      paged-AMS greedy legitimately differs from contiguous because KV
      storage is lossy);
  (b) the rejection rule preserves the target distribution at
      temperature > 0: each emitted position marginally follows the
      exact tempered/masked softmax (chi-square, hypothesis property +
      deterministic mirror), and seeded speculative streams replay
      bit-identically across engine restarts, slot counts and chunking;
  (c) rollback of rejected drafts never touches shared prefix-cache
      pages (pinned with an always-rejected drafter + a byte-level
      snapshot of the published pages), and `stats()` accept-rate /
      tokens-per-step accounting is exact (pinned with an oracle
      drafter whose proposals are the target's own future tokens).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import CacheConfig
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.launch.engine import ServeEngine
from repro.launch.mesh import make_driver_mesh
from repro.launch.sampling import (
    SamplingParams,
    fill_slot,
    request_key,
    slot_batch,
)
from repro.launch.speculative import (
    Drafter,
    NgramDrafter,
    SelfDrafter,
    make_drafter,
    verify_tokens,
)
from repro.launch.steps import build_engine_step
from repro.models.attention import cache_truncate_chunk

ARCH = "qwen2-7b"
SCHEME = "fp5.33-e2m3"
CAP = 32
VOCAB = 512

CACHE_CFGS = {
    "contiguous": None,
    "paged_bf16": CacheConfig(kind="paged_bf16", page_size=8),
    "paged_ams": CacheConfig(kind="paged_ams", page_size=8),
}


def engine(mode="contiguous", slots=2, chunk=1, k=0, drafter="ngram",
           capacity=CAP):
    return ServeEngine(ARCH, scheme=SCHEME, slots=slots, capacity=capacity,
                       seed=0, prefill_chunk=chunk, speculate_k=k,
                       drafter=drafter, cache_config=CACHE_CFGS[mode])


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(11)
    return [rng.integers(0, VOCAB, n) for n in (5, 9, 12)]


def run_all(eng, prompts, mt=8, sampling=None):
    reqs = [eng.submit(p, mt, sampling=None if sampling is None else sampling[i])
            for i, p in enumerate(prompts)]
    eng.run()
    assert all(r.done for r in reqs)
    return [list(r.tokens) for r in reqs]


# ---------------------------------------------------------------------------
# test drafters: an oracle (always right) and its negation (always wrong)
# ---------------------------------------------------------------------------
class OracleDrafter(Drafter):
    """Proposes the target's own future tokens, replayed from precomputed
    reference streams — accept_rate 1.0 by construction, which makes the
    stats() accounting exactly predictable."""

    name = "oracle"

    def __init__(self, table):
        # table: [(prompt, stream)] from a non-speculative reference run
        self.table = [(np.asarray(p, np.int32).reshape(-1), list(s))
                      for p, s in table]

    def propose(self, history, k):
        h = np.asarray(history, np.int32)
        for p, s in self.table:
            n = p.shape[0]
            g = h.shape[0] - n
            if g >= 0 and np.array_equal(h[:n], p) \
                    and list(h[n:]) == s[:g]:
                return np.asarray(s[g:g + k], np.int32)
        return np.zeros(0, np.int32)


class ShiftedDrafter(OracleDrafter):
    """(truth + 1) mod vocab: every draft is rejected at temperature 0, so
    every decode round exercises the rollback path."""

    name = "shifted"

    def propose(self, history, k):
        d = super().propose(history, k)
        return (d + 1) % VOCAB if d.size else d


# ---------------------------------------------------------------------------
# drafter unit tests
# ---------------------------------------------------------------------------
def test_ngram_drafter_matches_most_recent_occurrence():
    d = NgramDrafter(max_ngram=3)
    # trailing [1, 2] occurred at position 0 -> propose what followed: 3, 1
    got = d.propose(np.array([1, 2, 3, 1, 2]), 2)
    np.testing.assert_array_equal(got, [3, 1])
    # two occurrences of the trailing 1-gram: the MOST RECENT one wins
    got = d.propose(np.array([7, 5, 8, 5, 9, 5]), 1)
    np.testing.assert_array_equal(got, [9])
    # longest n-gram wins over a shorter, more recent match
    got = d.propose(np.array([1, 2, 3, 9, 3, 4, 1, 2, 3]), 1)
    np.testing.assert_array_equal(got, [9])


def test_ngram_drafter_empty_on_no_match():
    d = NgramDrafter()
    assert d.propose(np.array([1, 2, 3, 4]), 2).size == 0
    assert d.propose(np.array([5]), 2).size == 0          # too short to match
    with pytest.raises(ValueError, match="min_ngram"):
        NgramDrafter(max_ngram=2, min_ngram=3)


def test_self_drafter_deterministic_and_validated():
    cfg = get_config(ARCH).reduced()
    from repro.models import init_params
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.ndim >= 2 else x,
        init_params(jax.random.PRNGKey(0), cfg, tp=1))
    d = SelfDrafter(params, cfg, 16, draft_groups=None)   # full stack
    h = np.arange(5, dtype=np.int32)
    out = d.propose(h, 3)
    assert out.shape == (3,) and out.dtype == np.int32
    np.testing.assert_array_equal(out, d.propose(h, 3))   # deterministic
    # long histories are truncated into the fixed buffer, never overflow
    assert d.propose(np.arange(40, dtype=np.int32) % VOCAB, 3).shape == (3,)
    with pytest.raises(ValueError, match="draft_groups"):
        SelfDrafter(params, cfg, 16, draft_groups=99)
    with pytest.raises(ValueError, match="unknown drafter"):
        make_drafter("medusa")


# ---------------------------------------------------------------------------
# verify_tokens unit tests (greedy accept/emit/terminate, no engine)
# ---------------------------------------------------------------------------
def _samp(n, sps, ngen=None):
    batch = slot_batch(n)
    for s, sp in enumerate(sps):
        fill_slot(batch, s, sp, request_key(sp.seed, s),
                  sp.max_tokens if sp.max_tokens is not None else 1_000_000)
        if ngen is not None:
            batch["ngen"][s] = ngen[s]
    return {k: jnp.asarray(v) for k, v in batch.items()}


def _onehotish(tok, v=8):
    """Logits whose argmax is `tok` (and no near-ties)."""
    x = np.full(v, -4.0, np.float32)
    x[tok] = 4.0
    return x


def test_verify_tokens_greedy_accept_and_reject():
    # slot 0: both drafts match the running argmax -> accept 2, emit 3
    # slot 1: first draft wrong -> accept 0, emit the corrective argmax
    # slot 2: ndraft=0 (plain decode row) -> exactly one emitted argmax
    logits = jnp.asarray(np.stack([
        np.stack([_onehotish(3), _onehotish(5), _onehotish(6)]),
        np.stack([_onehotish(4), _onehotish(5), _onehotish(6)]),
        np.stack([_onehotish(7), _onehotish(0), _onehotish(0)]),
    ]))                                                   # [3, K+1=3, 8]
    token = jnp.asarray(np.array([[9, 3, 5], [9, 3, 5], [9, 0, 0]], np.int32))
    out, n_emit, acc, done = verify_tokens(
        logits, token, jnp.asarray([3, 3, 1], jnp.int32),
        jnp.asarray([2, 2, 0], jnp.int32),
        _samp(3, [SamplingParams()] * 3), k_max=2)
    np.testing.assert_array_equal(np.asarray(acc), [2, 0, 0])
    np.testing.assert_array_equal(np.asarray(n_emit), [3, 1, 1])
    np.testing.assert_array_equal(np.asarray(out)[0], [3, 5, 6])
    assert np.asarray(out)[1, 0] == 4 and np.asarray(out)[2, 0] == 7
    assert not np.asarray(done).any()


def test_verify_tokens_stop_token_truncates_mid_round():
    # drafts [3, 5] both accepted, but 3 is a stop token: the round ends at
    # emitted index 0 even though acc == 2
    logits = jnp.asarray(np.stack([
        np.stack([_onehotish(3), _onehotish(5), _onehotish(6)])]))
    token = jnp.asarray(np.array([[9, 3, 5]], np.int32))
    out, n_emit, acc, done = verify_tokens(
        logits, token, jnp.asarray([3], jnp.int32), jnp.asarray([2], jnp.int32),
        _samp(1, [SamplingParams(stop_token_ids=(3,))]), k_max=2)
    assert int(acc[0]) == 2 and int(n_emit[0]) == 1 and bool(done[0])
    assert int(out[0, 0]) == 3


def test_verify_tokens_length_cap_truncates_mid_round():
    # ngen=5, max_tokens=7: emitted index 1 hits the cap -> emit 2, done
    logits = jnp.asarray(np.stack([
        np.stack([_onehotish(3), _onehotish(5), _onehotish(6)])]))
    token = jnp.asarray(np.array([[9, 3, 5]], np.int32))
    out, n_emit, acc, done = verify_tokens(
        logits, token, jnp.asarray([3], jnp.int32), jnp.asarray([2], jnp.int32),
        _samp(1, [SamplingParams(max_tokens=7)], ngen=[5]), k_max=2)
    assert int(acc[0]) == 2 and int(n_emit[0]) == 2 and bool(done[0])
    np.testing.assert_array_equal(np.asarray(out)[0, :2], [3, 5])


def test_step_builder_validation():
    cfg = get_config(ARCH).reduced()
    rcfg = RunConfig(model=cfg, seq_len=CAP, global_batch=2, mode="decode",
                     quant=None)
    mesh = make_driver_mesh("none")
    with pytest.raises(ValueError, match="sampling"):
        build_engine_step(mesh, cfg, rcfg, chunk=4, sampling=False,
                          speculate_k=2)
    with pytest.raises(ValueError, match="chunk"):
        build_engine_step(mesh, cfg, rcfg, chunk=2, sampling=True,
                          speculate_k=2)
    with pytest.raises(ValueError, match="speculate_k"):
        ServeEngine(ARCH, scheme=SCHEME, slots=1, capacity=CAP,
                    speculate_k=-1)


def test_cache_truncate_chunk_zeroes_exact_rows():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((3, 10, 2, 4)).astype(np.float32)
    start = jnp.asarray([2, 5, 8], jnp.int32)
    count = jnp.asarray([3, 0, 3], jnp.int32)             # slot 2 runs OOB
    out = np.asarray(cache_truncate_chunk(jnp.asarray(x), start, count, 4))
    want = x.copy()
    want[0, 2:5] = 0
    want[2, 8:10] = 0                                     # 10.. dropped, no wrap
    np.testing.assert_array_equal(out, want)


# ---------------------------------------------------------------------------
# (b) rejection rule preserves the target distribution (chi-square)
# ---------------------------------------------------------------------------
# hardcoded chi-square critical value: df = 7 (8 vocab bins), alpha = 1e-4.
# alpha is deliberately tiny: the statistic scales linearly with the sample
# count for a WRONG distribution (power is enormous at n=4096), while the
# false-positive rate stays at alpha per example.
CHI2_CRIT_DF7 = 29.877

try:
    from scipy import stats as sp_stats
    assert abs(sp_stats.chi2.ppf(1 - 1e-4, df=7) - CHI2_CRIT_DF7) < 1e-2
except ImportError:                                       # pragma: no cover
    pass


def _first_emit_counts(logits_row, draft, n):
    """n independent samples of the round's FIRST emitted token (one slot
    per sample, distinct request keys), as vocab counts. The marginal law:
    accept the point-mass draft w.p. p(draft), else resample from p with
    the draft excluded and renormalized — which composes back to exactly p."""
    v = logits_row.shape[-1]
    batch = slot_batch(n)
    for s in range(n):
        fill_slot(batch, s, SamplingParams(temperature=1.0, seed=0),
                  request_key(0, s), 1_000_000)
    samp = {k: jnp.asarray(vv) for k, vv in batch.items()}
    token = np.zeros((n, 2), np.int32)
    token[:, 1] = draft
    logits = jnp.broadcast_to(
        jnp.asarray(logits_row, jnp.float32)[None, None, :], (n, 2, v))
    out, _, _, _ = verify_tokens(
        logits, jnp.asarray(token), jnp.full(n, 2, jnp.int32),
        jnp.ones(n, jnp.int32), samp, k_max=1)
    return np.bincount(np.asarray(out)[:, 0], minlength=v)


def _chi2(counts, logits_row):
    p = np.exp(logits_row - logits_row.max())
    p /= p.sum()
    e = counts.sum() * p
    return float(((counts - e) ** 2 / e).sum())


def test_rejection_preserves_target_distribution():
    """Deterministic mirror of the hypothesis property below (always runs):
    the first emitted token's marginal equals the exact softmax, for a
    high-probability and a low-probability draft."""
    rng = np.random.default_rng(7)
    logits = rng.uniform(-1.5, 1.5, 8).astype(np.float32)
    for draft in (int(np.argmax(logits)), int(np.argmin(logits))):
        counts = _first_emit_counts(logits, draft, 4096)
        chi2 = _chi2(counts, logits)
        assert chi2 < CHI2_CRIT_DF7, (draft, chi2, counts)
    # power check: a deliberately wrong law (always emit the draft — what a
    # missing rejection step would produce) fails the same test
    fake = np.zeros(8, np.int64)
    fake[3] = 4096
    assert _chi2(fake, logits) > CHI2_CRIT_DF7


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                       # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*a, **k):                                   # keep the def importable
        return lambda f: f

    settings = given
    st = None


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=10, deadline=None)
@given(st.lists(st.floats(-1.5, 1.5), min_size=8, max_size=8)
       if HAVE_HYPOTHESIS else None,
       st.integers(0, 7) if HAVE_HYPOTHESIS else None)
def test_rejection_preserves_target_distribution_property(logits, draft):
    """Property form: ANY bounded logit row and ANY point-mass draft keep
    the emitted marginal chi-square-consistent with the exact softmax."""
    logits = np.asarray(logits, np.float32)
    counts = _first_emit_counts(logits, draft, 2048)
    assert _chi2(counts, logits) < CHI2_CRIT_DF7, (logits, draft, counts)


# ---------------------------------------------------------------------------
# (a) greedy stream equivalence: spec ≡ non-spec within each cache mode
# ---------------------------------------------------------------------------
_BASELINES = {}


def _baseline(mode, chunk, prompts, mt=8):
    key = (mode, chunk, mt)
    if key not in _BASELINES:
        _BASELINES[key] = run_all(engine(mode, chunk=chunk), prompts, mt)
    return _BASELINES[key]


def _assert_spec_equivalent(mode, chunk, drafter, k, prompts, mt=8):
    want = _baseline(mode, chunk, prompts, mt)
    eng = engine(mode, chunk=chunk, k=k, drafter=drafter)
    got = run_all(eng, prompts, mt)
    for j, (a, b) in enumerate(zip(want, got)):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"{mode} C={chunk} {drafter} k={k}: request {j} "
                    f"speculative stream diverged from non-speculative")
    s = eng.stats()
    if drafter in ("self", "self-full"):
        assert s["spec_proposed"] > 0       # self drafters always propose
    return s


def test_greedy_equivalence_smoke(prompts):
    """Fast pins: the production shape (paged-AMS, chunked, n-gram) and a
    high-accept self-draft run with real multi-token emissions."""
    _assert_spec_equivalent("paged_ams", 4, "ngram", 4, prompts)
    s = _assert_spec_equivalent("contiguous", 1, "self-full", 2, prompts)
    assert s["accept_rate"] > 0             # full-stack drafts mostly land


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["contiguous", "paged_bf16", "paged_ams"])
@pytest.mark.parametrize("chunk", [1, 4])
@pytest.mark.parametrize("drafter", ["ngram", "self"])
@pytest.mark.parametrize("k", [1, 2, 4])
def test_greedy_equivalence_grid(mode, chunk, drafter, k, prompts):
    """Full acceptance grid: cache mode × chunk × drafter × k ∈ {1,2,4}.
    The truncated-stack self drafter is usually WRONG on random weights —
    which is the point: near-zero accept rates stress rollback on every
    round, and the streams must still be bit-identical."""
    _assert_spec_equivalent(mode, chunk, drafter, k, prompts)


# ---------------------------------------------------------------------------
# (b) seeded sampled replay determinism
# ---------------------------------------------------------------------------
def test_sampled_replay_across_restart_slots_and_chunk():
    """temperature>0 speculative streams replay bit-identically across a
    fresh engine, a different slot count, and a different prefill chunk —
    the decision keys fold request id + token index only."""
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, VOCAB, n) for n in (6, 9, 11)]
    sampling = [SamplingParams(temperature=0.8, top_p=0.9, seed=100 + i)
                for i in range(3)]
    runs = [run_all(engine("paged_ams", slots=s, chunk=c, k=2, drafter="ngram"),
                    prompts, 8, sampling=sampling)
            for s, c in ((2, 1), (2, 1), (3, 4))]
    for other in runs[1:]:
        for j, (a, b) in enumerate(zip(runs[0], other)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"request {j}: seeded speculative replay diverged")


# ---------------------------------------------------------------------------
# (c) rollback never touches shared prefix pages
# ---------------------------------------------------------------------------
def test_rollback_never_touches_shared_prefix_pages():
    """An always-rejected drafter forces a rollback EVERY decode round of
    every request. The published system-prompt pages must stay byte-
    identical through all of it, and later requests that pin them must
    still produce the non-speculative streams."""
    rng = np.random.default_rng(3)
    sys_prompt = rng.integers(0, VOCAB, 16)               # two full pages
    prompts = [np.concatenate([sys_prompt, rng.integers(0, VOCAB, n)])
               for n in (3, 5, 4)]
    work = [(0, prompts[0]), (22, prompts[1]), (26, prompts[2])]

    def drive(eng, snapshot_after=None):
        reqs, pending, snap = [], list(work), None
        while pending or eng.has_work:
            while pending and pending[0][0] <= eng.tick:
                _, p = pending.pop(0)
                reqs.append(eng.submit(p, 6))
            eng.step()
            if snapshot_after is not None and snap is None \
                    and reqs[0].done:
                snap = snapshot_after(eng, reqs[0])
        return reqs, snap

    base = ServeEngine(ARCH, scheme=SCHEME, slots=2, capacity=CAP, seed=0,
                       cache_config=CACHE_CFGS["paged_ams"])
    want, _ = drive(base)

    def pages_bytes(eng, r0):
        pages = list(r0.pages[:2])        # the two published prompt pages
        return [np.asarray(leaf[:, pages]).copy()
                for leaf in jax.tree.leaves(eng.cache)], pages

    eng = ServeEngine(ARCH, scheme=SCHEME, slots=2, capacity=CAP, seed=0,
                      speculate_k=2,
                      drafter=ShiftedDrafter([(p, list(r.tokens))
                                              for p, r in zip(prompts, want)]),
                      cache_config=CACHE_CFGS["paged_ams"])
    got, (snap, pages) = drive(eng, snapshot_after=pages_bytes)

    s = eng.stats()
    assert s["spec_proposed"] > 0 and s["spec_accepted"] == 0
    assert s["accept_rate"] == 0.0        # every round rolled back
    for j, (a, b) in enumerate(zip(want, got)):
        np.testing.assert_array_equal(
            np.asarray(a.tokens), np.asarray(b.tokens),
            err_msg=f"request {j} diverged under permanent rollback")
    for r in got[1:]:
        assert r.cached_len == 16         # later requests pinned the pages
    # byte-level pin: the published pages never changed under rollbacks
    for before, leaf in zip(snap, jax.tree.leaves(eng.cache)):
        np.testing.assert_array_equal(before, np.asarray(leaf[:, pages]))
    eng.alloc.check_invariants()
    assert s["pages_in_use"] == 0
    assert s["free_pages"] == eng.cache_cfg.num_pages


# ---------------------------------------------------------------------------
# (c) accept-rate / tokens-per-step accounting
# ---------------------------------------------------------------------------
def test_accept_rate_accounting_with_oracle_drafter(prompts):
    """Oracle proposals (the target's own future tokens) accept 100%:
    spec_accepted == spec_proposed, accept_rate == 1.0, and tokens_per_step
    follows exactly from the emitted-round count."""
    want = _baseline("paged_ams", 1, prompts)
    eng = engine("paged_ams", k=4,
                 drafter=OracleDrafter(list(zip(prompts, want))))
    got = run_all(eng, prompts)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s = eng.stats()
    assert s["spec_proposed"] > 0
    assert s["spec_accepted"] == s["spec_proposed"]
    assert s["accept_rate"] == 1.0
    # mt=8, k=4: rounds emit 1 (prefill), 5, 2 -> 8 tokens over 3 rounds
    assert s["tokens_per_step"] == pytest.approx(
        s["tokens_generated"] / eng._emit_rounds)
    assert s["tokens_per_step"] > 1.5


def test_non_speculative_stats_are_neutral():
    eng = engine("contiguous")
    rng = np.random.default_rng(0)
    run_all(eng, [rng.integers(0, VOCAB, 5)], mt=4)
    s = eng.stats()
    assert s["spec_proposed"] == 0 and s["spec_accepted"] == 0
    assert s["accept_rate"] == 0.0
    assert s["tokens_per_step"] == 1.0    # every emission is a single draw
