"""Pallas kernel validation (interpret mode) against the pure-jnp oracle.

Per the brief: sweep shapes/dtypes per kernel and assert_allclose vs ref.py.
The kernel rounds activations to bf16 (MXU input format); the oracle is fed
bf16-rounded activations so the comparison isolates kernel correctness.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SCHEMES, get_scheme, quantize_linear
from repro.kernels import ops, ref


def mk(K, N, B, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32) * 0.02)
    x = jnp.asarray(rng.standard_normal((B, K)).astype(np.float32)).astype(dtype)
    return w, x


def oracle(x, pw):
    xb = x.astype(jnp.bfloat16).astype(jnp.float32)
    return ref.ams_matmul_ref(xb, pw)


@pytest.mark.parametrize("scheme", list(SCHEMES))
def test_all_schemes_basic(scheme):
    s = SCHEMES[scheme]
    w, x = mk(640, 256, 4, seed=1)
    q = quantize_linear(w, s)
    y = ops.ams_matmul(x, q.packed, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle(x, q.packed)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "K,N,B",
    [
        (128, 128, 1),      # GEMV decode, minimal tile
        (700, 300, 5),      # ragged everything
        (1536, 512, 16),    # multi-tile K and N
        (384, 1, 2),        # single output channel
        (1, 256, 3),        # single input channel
        (2048, 640, 33),    # ragged B over block_b
    ],
)
def test_shape_sweep_fp533(K, N, B):
    s = get_scheme("fp5.33-e2m3")
    w, x = mk(K, N, B, seed=K + N + B)
    q = quantize_linear(w, s)
    y = ops.ams_matmul(x, q.packed, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle(x, q.packed)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("K,N,B", [(512, 384, 7), (1000, 200, 2)])
def test_shape_sweep_fp425(K, N, B):
    s = get_scheme("fp4.25-e2m2")
    w, x = mk(K, N, B, seed=K * 3 + N + B)
    q = quantize_linear(w, s)
    y = ops.ams_matmul(x, q.packed, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle(x, q.packed)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_sweep(dtype):
    s = get_scheme("fp5.33-e2m3")
    w, x = mk(384, 256, 8, seed=11, dtype=dtype)
    q = quantize_linear(w, s)
    y = ops.ams_matmul(x, q.packed, interpret=True)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(oracle(x.astype(jnp.float32), q.packed)),
        rtol=1e-5, atol=1e-5)


def test_leading_batch_dims():
    s = get_scheme("fp4.25-e2m2")
    w, _ = mk(256, 128, 1, seed=12)
    q = quantize_linear(w, s)
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.standard_normal((2, 3, 256)).astype(np.float32))
    y = ops.ams_matmul(x, q.packed, interpret=True)
    assert y.shape == (2, 3, 128)
    y2 = ops.ams_matmul(x.reshape(6, 256), q.packed, interpret=True)
    np.testing.assert_allclose(np.asarray(y).reshape(6, 128), np.asarray(y2),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("block_k,block_n,block_b", [(384, 128, 8), (768, 512, 16)])
def test_block_shape_sweep(block_k, block_n, block_b):
    s = get_scheme("fp5.33-e2m3")
    w, x = mk(1152, 512, 16, seed=14)
    q = quantize_linear(w, s)
    y = ops.ams_matmul(x, q.packed, interpret=True,
                       block_k=block_k, block_n=block_n, block_b=block_b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle(x, q.packed)),
                               rtol=1e-5, atol=1e-5)


def test_blocked_xla_fallback_matches_oracle():
    for scheme in ("fp5.33-e2m3", "fp4.25-e2m2", "fp6-e2m3", "fp8"):
        s = SCHEMES[scheme]
        w, x = mk(999, 160, 6, seed=15)
        q = quantize_linear(w, s)
        y = ref.ams_matmul_blocked(x, q.packed)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref.ams_matmul_ref(x, q.packed)),
            rtol=1e-5, atol=1e-5)


def test_kernel_decode_bit_exact():
    """The in-kernel SHIFT/AND/OR decode must equal the table decode exactly.

    Checked by feeding one-hot activations through the kernel: row k of the
    result equals the dequantized weight row exactly (no rounding: bf16 holds
    every FPx<=8 value exactly, 1.0 activations are exact)."""
    s = get_scheme("fp5.33-e2m3")
    K, N = 384, 128
    w, _ = mk(K, N, 1, seed=16)
    q = quantize_linear(w, s)
    eye = jnp.eye(8, K, dtype=jnp.float32)  # first 8 rows
    y = ops.ams_matmul(eye, q.packed, interpret=True)
    wd = ref.dequant_full(q.packed)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(wd[:8]))
