"""The fused attention template (`repro.kernels.attention_template`).

Contract: ONE grid/loop body serves every decode path, and every lowering
is pinned to the same oracle family —

  * ``impl="ref"`` IS `flash_decode`/`flash_decode_chunk` (bit-identical:
    `attend_contiguous` must return the very same arrays the pre-template
    cores computed), and unfusable cases (mesh collectives, ring/sliding
    window, non-group-major head maps) silently keep that path;
  * the fused Pallas lowering (interpret mode here) agrees with the XLA
    oracle to f32-reduction tolerance across the full
    {gqa, mla} x {contiguous, paged_bf16, paged_ams} x chunk {1, 4} grid,
    idle slots / masked ragged rows flushing to EXACT zeros;
  * the whole engine decodes through the fused contiguous path.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import CacheConfig, gather_kv, make_gqa_page_pool, paged_insert
from repro.kernels.attention_template import (
    attend_contiguous,
    flash_decode,
    flash_decode_chunk,
    fused_contiguous_attention,
    fused_paged_attention,
)
from repro.launch.engine import ServeEngine

B, KV, H, HD = 2, 2, 4, 32
R_KV = 16                      # MLA value slice of the compressed stream


# ------------------------------------------------------------------ fixtures
def _dense_case(family, chunk, seed=0, dtype=jnp.float32, S=16):
    """(q, k_cache, v_cache, lengths, kv_map, value_slice): slot 1 idle /
    mostly-masked so exact-zero rows are exercised in every cell."""
    rng = np.random.default_rng(seed)
    kv = 1 if family == "mla" else KV
    k = jnp.asarray(rng.standard_normal((B, S, kv, HD)), dtype=dtype)
    v = jnp.asarray(rng.standard_normal((B, S, kv, HD)), dtype=dtype)
    if chunk == 1:
        q = jnp.asarray(rng.standard_normal((B, H, HD)), dtype=dtype)
        lengths = jnp.asarray([13, 0], jnp.int32)          # slot 1 idle
    else:
        q = jnp.asarray(rng.standard_normal((B, chunk, H, HD)), dtype=dtype)
        lengths = jnp.asarray([[10, 11, 12, 13], [7, 0, 0, 0]], jnp.int32)
    kvm = np.zeros(H, np.int32) if kv == 1 else np.arange(H) // (H // kv)
    vs = R_KV if family == "mla" else None
    return q, k, v, lengths, kvm, vs


def _oracle(q, k, v, lengths, kvm, vs, **kw):
    v = k[..., :vs] if vs is not None else v
    if q.ndim == 3:
        return flash_decode(q, k, v, lengths, kv_map=kvm, **kw)
    return flash_decode_chunk(q, k, v, lengths, kv_map=kvm, **kw)


def _filled_pool(ccfg, kv, hd, lens, seed=0):
    rng = np.random.default_rng(seed)
    pool = make_gqa_page_pool(ccfg, kv, hd)
    perm = rng.permutation(ccfg.num_pages)[: B * ccfg.max_pages_per_seq]
    bt = jnp.asarray(perm.reshape(B, -1).astype(np.int32))
    for t in range(max(lens)):
        k_new = jnp.asarray(rng.standard_normal((B, 1, kv, hd)), jnp.bfloat16)
        v_new = jnp.asarray(rng.standard_normal((B, 1, kv, hd)), jnp.bfloat16)
        pos = jnp.asarray(np.where(t < np.asarray(lens), t, -1), jnp.int32)
        pool = paged_insert(pool, k_new, v_new, pos, bt, ccfg)
    return pool, bt


# ------------------------------------------------- ref tier + dispatch rules
def test_ref_impl_is_flash_decode_bitwise():
    for chunk in (1, 4):
        q, k, v, lengths, kvm, _ = _dense_case("gqa", chunk)
        got = attend_contiguous(q, k, v, lengths, kv_map=kvm, impl="ref")
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(_oracle(q, k, v, lengths, kvm, None)))


def test_unfusable_cases_fall_back_to_ref_bitwise():
    """window/ring and non-group-major head maps must keep the XLA path
    even when the fused impl is requested — same bits, no lowering error."""
    q, k, v, lengths, kvm, _ = _dense_case("gqa", 1)
    got = attend_contiguous(q, k, v, lengths, kv_map=kvm,
                            impl="pallas_interpret", window=4)
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(flash_decode(q, k, v, lengths, kv_map=kvm, window=4)))
    scrambled = np.array([1, 0, 1, 0], np.int32)      # not group-major
    got = attend_contiguous(q, k, v, lengths, kv_map=scrambled,
                            impl="pallas_interpret")
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(flash_decode(q, k, v, lengths, kv_map=scrambled)))


def test_fused_contiguous_validation():
    q, k, v, lengths, kvm, _ = _dense_case("gqa", 1)
    with pytest.raises(ValueError, match="v_cache or value_slice"):
        fused_contiguous_attention(q, k, lengths, interpret=True)
    with pytest.raises(ValueError, match="divide"):
        fused_contiguous_attention(q, k, lengths, v_cache=v, block_kv=5,
                                   interpret=True)


def test_template_is_the_single_home():
    """models.attention and cache.paged_attention serve the template's own
    objects — the duplicated loop bodies are gone, not just unused."""
    from repro.cache import paged_attention as pa
    from repro.kernels import attention_template as tpl
    from repro.models import attention as A
    assert A.flash_decode is tpl.flash_decode
    assert A.flash_decode_chunk is tpl.flash_decode_chunk
    assert pa.online_softmax_step is tpl.online_softmax_step
    assert pa.restore_page is tpl.restore_page


# --------------------------------------- the fused grid, pinned to the oracle
@pytest.mark.slow
@pytest.mark.parametrize("chunk", [1, 4])
@pytest.mark.parametrize("family", ["gqa", "mla"])
def test_fused_contiguous_matches_ref(family, chunk):
    q, k, v, lengths, kvm, vs = _dense_case(family, chunk)
    want = _oracle(q, k, v, lengths, kvm, vs)
    got = attend_contiguous(q, k, v if vs is None else k[..., :vs], lengths,
                            kv_map=kvm, impl="pallas_interpret",
                            value_slice=vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=1e-6)
    assert np.all(np.asarray(got)[1] == 0) == (chunk == 1)   # idle slot
    if chunk == 4:
        assert np.all(np.asarray(got)[1, 1:] == 0)   # masked ragged rows


@pytest.mark.slow
@pytest.mark.parametrize("chunk", [1, 4])
@pytest.mark.parametrize("family", ["gqa", "mla"])
@pytest.mark.parametrize("kind", ["paged_bf16", "paged_ams"])
def test_fused_paged_matches_ref(kind, family, chunk):
    """The paged lowerings against the gather -> (dequantize ->) attend
    oracle: same block-table walk, AMS planes restored to the exact
    lattice values the dense oracle dequantizes to."""
    ccfg = CacheConfig(kind=kind, page_size=4).sized(capacity=16, slots=B)
    kv = 1 if family == "mla" else KV
    lens = (13, 7) if chunk == 4 else (13, 0)
    pool, bt = _filled_pool(ccfg, kv, HD, lens)
    rng = np.random.default_rng(3)
    if chunk == 1:
        q = jnp.asarray(rng.standard_normal((B, H, HD)), jnp.float32)
        lengths = jnp.asarray(lens, jnp.int32)
    else:
        q = jnp.asarray(rng.standard_normal((B, chunk, H, HD)), jnp.float32)
        lengths = jnp.asarray([[10, 11, 12, 13], [7, 0, 0, 0]], jnp.int32)
    kvm = np.zeros(H, np.int32) if kv == 1 else np.arange(H) // (H // kv)
    vs = R_KV if family == "mla" else None
    # oracle attends the dense gathered view in the dtype the fused path
    # computes in: restored-f32 lattice values for AMS, bf16 pages else
    dtype = jnp.float32 if ccfg.quantized else jnp.bfloat16
    kd, vd = gather_kv(pool, bt, HD, ccfg, dtype=dtype)
    want = _oracle(q, kd, vd, lengths, kvm, vs)
    got = fused_paged_attention(
        q, pool, lengths, bt, page_size=ccfg.page_size,
        kv_scheme=ccfg.kv_scheme if ccfg.quantized else None,
        value_slice=vs, interpret=True)
    # AMS restores f32 lattice values -> f32-reduction tolerance; bf16
    # pages round p to bf16 at the RUNNING max (oracle: the global max),
    # so those cells agree only to bf16 precision
    atol, rtol = (2e-6, 1e-6) if ccfg.quantized else (2e-3, 2e-2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=atol, rtol=rtol)
    if chunk == 1:
        assert np.all(np.asarray(got)[1] == 0)       # idle slot: exact zeros
    else:
        assert np.all(np.asarray(got)[1, 1:] == 0)   # masked ragged rows


# ------------------------------------------------------- engine end-to-end
@pytest.mark.slow
def test_contiguous_engine_fused_end_to_end():
    """The CONTIGUOUS engine decodes through the fused template
    (CacheConfig(impl=...) now threads to the GQA cores): the workload
    completes and the step signature advertises the lowering."""
    rng = np.random.default_rng(7)
    work = [(rng.integers(0, 512, 5), 3), (rng.integers(0, 512, 3), 4)]
    eng = ServeEngine("qwen2-7b", scheme="fp5.33-e2m3", slots=2, capacity=16,
                      seed=0,
                      cache_config=CacheConfig(impl="pallas_interpret"))
    assert eng.signature["impl"] == "pallas_interpret"
    reqs = [eng.submit(p, mt) for p, mt in work]
    eng.run()
    assert [len(r.tokens) for r in reqs] == [mt for _, mt in work]
