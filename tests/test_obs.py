"""Observability subsystem (repro.obs): registry, spans, roofline floors.

The load-bearing contracts:

  (a) ZERO PERTURBATION: telemetry on vs `ObsConfig(enabled=False)` is
      bit-identical in engine behaviour — same ticks, same token streams,
      same lifecycle ticks (the committed bench baseline depends on this);
  (b) `stats()` is a VIEW over the registry: every legacy key reproduces
      the pre-telemetry hand-counter math exactly (the raw-observation
      histograms keep insertion order, so percentiles can't drift);
  (c) span lifecycle invariants hold under real traffic — queueing,
      chunked prefill, same-tick re-admission, speculative rollback:
      strict LIFO nesting per track, every span closed at drain;
  (d) the analytic KV floors in `obs.cost` are derived INDEPENDENTLY of
      `repro.cache` and must agree with the pool layout exactly — the
      measured engine bytes/token sits within 10% of the floor (it is
      exactly 1.0x), and layout drift in either module trips the test;
  (e) the Prometheus exposition round-trips through `parse_prom`.
"""

import json

import numpy as np
import pytest

from repro.cache import CacheConfig
from repro.cache.pool import pool_bytes_per_token
from repro.core.formats import get_scheme
from repro.launch.engine import ServeEngine
from repro.launch.sampling import SamplingParams
from repro.obs import (
    MetricsRegistry,
    ObsConfig,
    TraceRecorder,
    attribution,
    build_cost_model,
    kv_vector_bytes_floor,
    kv_vector_bytes_ideal,
    parse_prom,
    ticker_line,
    validate_events,
)
from repro.obs.metrics import NULL_REGISTRY

ARCH = "qwen2-7b"
SCHEME = "fp5.33-e2m3"
PAGE = 8
PREFIX = 16


# ===================================================== registry (no engine)
class TestRegistry:
    def test_counter_gauge_histogram_basics(self):
        m = MetricsRegistry()
        c = m.counter("c_total", "help")
        c.inc()
        c.inc(2.5)
        assert m.value("c_total") == 3.5
        g = m.gauge("g", "help")
        g.set(7)
        assert m.value("g") == 7.0
        h = m.histogram("h", "help", buckets=(1.0, 10.0))
        for v in (0.5, 5.0, 50.0):
            h.observe(v)
        assert h.count == 3 and h.sum == 55.5
        assert h.raw_values() == [0.5, 5.0, 50.0]   # insertion order

    def test_labels_get_or_create(self):
        m = MetricsRegistry()
        c = m.counter("req_total", "help", labelnames=("kind",))
        c.labels(kind="a").inc(2)
        c.labels(kind="b").inc(3)
        assert m.value("req_total", kind="a") == 2.0
        assert c.total == 5.0
        # same name, conflicting shape -> loud failure, not silent aliasing
        with pytest.raises(ValueError):
            m.counter("req_total", "help", labelnames=("other",))
        with pytest.raises(ValueError):
            m.gauge("req_total", "help")

    def test_callback_gauge_survives_reset(self):
        m = MetricsRegistry()
        state = {"v": 1.0}
        g = m.gauge("depth", "help", fn=lambda: state["v"])
        state["v"] = 42.0
        assert g.value == 42.0                # sampled at read time
        assert m.value("depth") == 42.0
        m.reset()
        assert g.value == 42.0                # reset keeps the callback

    def test_disabled_registry_is_inert(self):
        m = MetricsRegistry(enabled=False)
        c = m.counter("c_total", "help")
        c.inc(5)
        h = m.histogram("h", "help")
        h.observe(1.0)
        assert m.value("c_total") == 0.0
        assert h.raw_values() == []
        assert c is m.counter("other_total", "help")   # shared no-op
        assert NULL_REGISTRY.counter("x_total", "h").value == 0.0

    def test_exposition_round_trip(self):
        m = MetricsRegistry()
        m.counter("req_total", "reqs", labelnames=("mode",)).labels(
            mode='pa"ged\\x').inc(3)
        m.gauge("depth", "queue").set(2.5)
        h = m.histogram("lat_s", "latency", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 5.0):
            h.observe(v)
        parsed = parse_prom(m.exposition())
        assert parsed[("req_total", (("mode", 'pa"ged\\x'),))] == 3.0
        assert parsed[("depth", ())] == 2.5
        # cumulative buckets + exact sum/count
        assert parsed[("lat_s_bucket", (("le", "0.1"),))] == 1.0
        assert parsed[("lat_s_bucket", (("le", "1"),))] == 2.0
        assert parsed[("lat_s_bucket", (("le", "+Inf"),))] == 3.0
        assert parsed[("lat_s_count", ())] == 3.0
        assert parsed[("lat_s_sum", ())] == pytest.approx(5.55)

    def test_snapshot_jsonl(self, tmp_path):
        m = MetricsRegistry()
        m.counter("c_total", "help").inc(2)
        p = tmp_path / "m.jsonl"
        m.write_jsonl(str(p), extra={"run": "t1"})
        m.write_jsonl(str(p))
        lines = [json.loads(ln) for ln in p.read_text().splitlines()]
        assert len(lines) == 2 and lines[0]["run"] == "t1"
        fam = lines[1]["metrics"]["c_total"]
        assert fam["type"] == "counter"
        assert fam["values"][0]["value"] == 2.0


# ======================================================== spans (no engine)
class TestTrace:
    def _rec(self):
        t = {"now": 1_000_000}
        rec = TraceRecorder(clock=lambda: t["now"])
        return rec, t

    def test_nesting_and_export(self, tmp_path):
        rec, t = self._rec()
        rec.thread(0, "engine")
        rec.begin(0, "tick")
        t["now"] += 3000
        rec.begin(0, "device_step")
        t["now"] += 2000
        rec.end(0, "device_step")
        rec.instant(0, "finished")
        rec.counter("engine", {"active": 2})
        rec.end(0, "tick", args={"generated": 1})
        assert rec.open_spans() == {}
        spans = validate_events(rec.events())
        names = [(n, d) for n, _, _, d in spans[0]]
        assert ("tick", 0) in names and ("device_step", 1) in names
        p = tmp_path / "trace.json"
        rec.save(str(p))
        dumped = json.loads(p.read_text())
        phases = {e["ph"] for e in dumped["traceEvents"]}
        assert {"B", "E", "M", "i", "C"} <= phases

    def test_mismatched_end_raises_eagerly(self):
        rec, _ = self._rec()
        rec.begin(0, "tick")
        with pytest.raises(RuntimeError, match="nesting"):
            rec.end(0, "device_step")
        with pytest.raises(RuntimeError, match="nesting"):
            rec.end(1, "never_opened")

    def test_disabled_recorder_records_nothing(self):
        rec = TraceRecorder(enabled=False)
        rec.begin(0, "tick")
        rec.end(0, "wrong_name")     # no state -> no nesting check either
        assert rec.events() == []


# ============================================== KV floors (obs.cost, no jit)
class TestKVFloors:
    @pytest.mark.parametrize("kv_scheme", ["fp4.25-e2m2", "fp4.5-e2m2",
                                           "fp4.33-e2m2"])
    @pytest.mark.parametrize("hd", [32, 64, 128])
    @pytest.mark.parametrize("kv", [1, 2, 4])
    def test_format_floor_equals_pool_layout(self, kv, hd, kv_scheme):
        # obs.cost derives the floor from scheme params WITHOUT importing
        # repro.cache; the pool derives it from the packed page layout.
        # They must agree per vector at every geometry — drift in either
        # module lands here.
        ccfg = CacheConfig(kind="paged_ams", page_size=PAGE,
                           kv_scheme=kv_scheme)
        per_vec = kv_vector_bytes_floor(hd, get_scheme(kv_scheme))
        assert 2 * kv * per_vec == pool_bytes_per_token(kv, hd, ccfg)

    def test_ideal_floor_convergence(self):
        # fp4.25-e2m2: padding + word granularity vanish at hd=128 —
        # the format floor IS the paper floor there
        fmt = get_scheme("fp4.25-e2m2")
        assert kv_vector_bytes_floor(128, fmt) == \
            kv_vector_bytes_ideal(128, fmt) == 72.0
        # and the overhead at reduced dims is the documented ratio
        assert kv_vector_bytes_floor(32, fmt) / \
            kv_vector_bytes_ideal(32, fmt) == pytest.approx(8 / 7)
        assert kv_vector_bytes_floor(64, fmt) / \
            kv_vector_bytes_ideal(64, fmt) == pytest.approx(40 / 38)

    def test_bf16_cache_floor(self):
        from repro.configs import get_config
        cfg = get_config(ARCH).reduced()
        cm = build_cost_model(cfg, "fp16")   # no cache cfg -> bf16 KV
        per_tok = 2 * cfg.num_kv_heads * cfg.head_dim * 2
        assert cm.kv_bytes_per_token == cfg.num_layers * per_tok
        assert cm.kv_bytes_per_token == cm.kv_bf16_bytes_per_token

    def test_tick_floor_accounting(self):
        from repro.configs import get_config
        cfg = get_config(ARCH).reduced()
        cm = build_cost_model(cfg, SCHEME,
                              CacheConfig(kind="paged_ams", page_size=PAGE))
        assert cm.tick_floor_bytes(0, 0) == cm.weight_bytes   # weights always
        extra = cm.tick_floor_bytes(2, 10) - cm.weight_bytes
        assert extra == 12 * cm.kv_bytes_per_token
        assert cm.tick_floor_flops(2, 10) == \
            2 * cm.flops_per_token + 10 * cm.attn_flops_per_pos
        assert cm.step_time_floor_s(2, 10) > 0


# =========================================================== engine-coupled
def schedule():
    """Mixed traffic over a shared 16-token prefix: more requests than
    slots (queueing), greedy + sampled + stop-token streams (variable
    length, both finish reasons), arrivals timed so no tick is idle."""
    rng = np.random.default_rng(5)
    sys_prompt = rng.integers(0, 512, PREFIX)
    mk = lambda n: np.concatenate([sys_prompt, rng.integers(0, 512, n)])
    return [
        (0, mk(5), SamplingParams(max_tokens=6)),
        (0, mk(3), SamplingParams(max_tokens=8)),
        (2, mk(7), SamplingParams(temperature=0.9, top_p=0.9, seed=11,
                                  max_tokens=6)),
        # stop id 56 is this stream's (deterministic, seeded) 4th draw —
        # the request terminates mid-stream with finish_reason "stop"
        (3, mk(2), SamplingParams(max_tokens=10, seed=3, temperature=0.8,
                                  stop_token_ids=(56, 101, 202))),
    ]


def drive(eng, work):
    """Submit at each item's arrival tick, step until drained. Returns
    (requests, number of step() calls)."""
    reqs, pending, n_steps = [], list(work), 0
    while pending or eng.has_work:
        while pending and pending[0][0] <= eng.tick:
            _, prompt, sp = pending.pop(0)
            reqs.append(eng.submit(prompt, sampling=sp))
        eng.step()
        n_steps += 1
    assert all(r.done for r in reqs)
    return reqs, n_steps


def make_engine(obs=None, speculate_k=0):
    return ServeEngine(ARCH, scheme=SCHEME, slots=2, capacity=48, seed=0,
                       prefill_chunk=4,
                       speculate_k=speculate_k,
                       drafter="self-full" if speculate_k else "ngram",
                       cache_config=CacheConfig(kind="paged_ams",
                                                page_size=PAGE),
                       obs=obs)


@pytest.fixture(scope="module")
def traced():
    eng = make_engine(obs=ObsConfig(trace=True))
    reqs, n_steps = drive(eng, schedule())
    return eng, reqs, n_steps


@pytest.fixture(scope="module")
def spec_traced():
    eng = make_engine(obs=ObsConfig(trace=True), speculate_k=3)
    work = [(t, p, SamplingParams(max_tokens=sp.max_tokens))  # all greedy
            for t, p, sp in schedule()]
    reqs, n_steps = drive(eng, work)
    return eng, reqs, n_steps


class TestZeroPerturbation:
    def test_streams_and_ticks_identical_with_obs_off(self, traced):
        eng, reqs, _ = traced
        off = make_engine(obs=ObsConfig(enabled=False))
        reqs_off, _ = drive(off, schedule())
        assert eng.tick == off.tick
        for a, b in zip(reqs, reqs_off):
            assert a.tokens == b.tokens
            assert (a.first_token_tick, a.finish_tick, a.finish_reason) == \
                (b.first_token_tick, b.finish_tick, b.finish_reason)
        assert eng.kv_bytes_per_token() == off.kv_bytes_per_token()

    def test_disabled_obs_stats_are_inert_not_broken(self):
        off = make_engine(obs=ObsConfig(enabled=False))
        s = off.stats()
        assert s["ticks"] == 0 and s["requests_finished"] == 0
        # pure-state values stay real even with telemetry off
        assert s["kv_bytes_per_token"] > 0
        assert off.metrics is NULL_REGISTRY


class TestStatsBackwardCompat:
    def test_stats_pin_bit_identical(self, traced):
        """stats() must reproduce the pre-registry hand-counter math:
        recompute every legacy key from the finished Request objects (in
        finish order — exactly what the old implementation observed) and
        require equality, not approx."""
        eng, reqs, n_steps = traced
        s = eng.stats()
        fin = eng.finished
        assert s["ticks"] == n_steps          # workload has no idle ticks
        assert eng.metrics.value("serve_idle_ticks_total") == 0.0
        assert s["requests_finished"] == len(fin) == len(reqs)
        assert s["tokens_generated"] == sum(r.n_generated for r in fin)
        ttft = np.asarray([r.ttft_ticks for r in fin], np.float64)
        e2e = np.asarray([r.latency_ticks for r in fin], np.float64)
        glen = np.asarray([r.n_generated for r in fin], np.float64)
        assert s["ttft_ticks_mean"] == float(ttft.mean())
        assert s["ttft_ticks_p50"] == float(np.percentile(ttft, 50))
        assert s["ttft_ticks_p99"] == float(np.percentile(ttft, 99))
        assert s["latency_ticks_mean"] == float(e2e.mean())
        assert s["latency_ticks_p50"] == float(np.percentile(e2e, 50))
        assert s["latency_ticks_p99"] == float(np.percentile(e2e, 99))
        assert s["gen_tokens_mean"] == float(glen.mean())
        assert s["stopped_early"] == \
            sum(r.finish_reason == "stop" for r in fin)
        assert s["stopped_early"] >= 1        # the stop-token request hit
        # non-speculative: every emission is one draw
        assert s["tokens_per_step"] == 1.0 and s["accept_rate"] == 0.0
        # prefix cache keys still flow through stats
        assert s["prefix_hit_rate"] > 0 and s["cached_token_frac"] > 0

    def test_live_exposition_matches_stats(self, traced):
        eng, reqs, _ = traced
        s = eng.stats()
        parsed = parse_prom(eng.metrics.exposition())
        assert parsed[("serve_device_steps_total", ())] == float(s["ticks"])
        assert parsed[("serve_requests_finished_total",
                       (("reason", "stop"),))] == float(s["stopped_early"])
        assert parsed[("serve_request_ttft_ticks_count", ())] == len(reqs)
        assert parsed[("sched_requests_submitted_total", ())] == \
            float(len(reqs))
        assert ("alloc_pages_total", (("kind", "shared"),)) in parsed

    def test_ticker_line(self, traced):
        eng, _, _ = traced
        line = ticker_line(eng)
        assert "B/tok" in line and "x floor" in line and "act" in line


class TestSpans:
    def _tracks(self, eng):
        spans = validate_events(eng.trace.events())   # raises on violation
        assert eng.trace.open_spans() == {}           # all closed at drain
        return spans

    def test_request_lifecycle_spans(self, traced):
        eng, reqs, _ = traced
        spans = self._tracks(eng)
        for r in reqs:
            names = [n for n, _, _, _ in spans[r.rid + 1]]
            # one full lifecycle per request track (spans listed in
            # completion order: the request umbrella closes last)
            assert names == ["queued", "prefill", "decode", "request"]
            by = {n: (b, e) for n, b, e, _ in spans[r.rid + 1]}
            assert by["queued"][1] <= by["prefill"][0]
            assert by["prefill"][1] <= by["decode"][0]
            # lifecycle spans nest inside the request umbrella span
            assert by["request"][0] <= by["queued"][0]
            assert by["decode"][1] <= by["request"][1]

    def test_engine_tick_spans(self, traced):
        eng, _, n_steps = traced
        spans = self._tracks(eng)
        ticks = [x for x in spans[0] if x[0] == "tick"]
        steps = [x for x in spans[0] if x[0] == "device_step"]
        # the warmup tick traces too; every tick nests >= 1 device step
        assert len(ticks) >= n_steps and len(steps) >= n_steps
        assert all(d == 0 for _, _, _, d in ticks)
        assert all(d == 1 for _, _, _, d in steps)

    def test_spans_survive_speculative_rollback(self, spec_traced):
        """Speculative traffic (drafts scored + rolled back in-step,
        multi-token emission rounds, early finishes freeing slots
        mid-tick) must not bend the span lifecycle."""
        eng, reqs, _ = spec_traced
        spans = self._tracks(eng)
        s = eng.stats()
        assert s["spec_proposed"] > 0
        assert 0 < s["spec_accepted"] <= s["spec_proposed"]
        assert s["tokens_per_step"] > 1.0     # speculation actually paid
        for r in reqs:
            names = [n for n, _, _, _ in spans[r.rid + 1]]
            assert names == ["queued", "prefill", "decode", "request"]

    def test_spec_streams_unchanged_by_telemetry(self, spec_traced):
        eng, reqs, _ = spec_traced
        off = make_engine(obs=ObsConfig(enabled=False), speculate_k=3)
        work = [(t, p, SamplingParams(max_tokens=sp.max_tokens))
                for t, p, sp in schedule()]
        reqs_off, _ = drive(off, work)
        for a, b in zip(reqs, reqs_off):
            assert a.tokens == b.tokens


class TestRoofline:
    def test_measured_kv_bytes_within_floor_tolerance(self, traced):
        """The acceptance bar: measured paged-AMS bytes/token vs the
        independently derived analytic floor, within 10%. (It is in fact
        EXACT — any non-1.0 ratio is a layout change in pool or cost.)"""
        eng, _, _ = traced
        s = eng.stats()
        assert abs(s["kv_floor_ratio"] - 1.0) <= 0.10
        assert s["kv_floor_ratio"] == 1.0
        assert s["kv_bytes_per_token"] == s["kv_bytes_per_token_floor"]
        # reduced dims (hd=32): the ideal/paper floor gap is the padding
        assert s["kv_vs_ideal_floor"] == pytest.approx(8 / 7)

    def test_attribution_report(self, traced):
        eng, _, _ = traced
        rep = attribution(eng)
        s = eng.stats()
        assert rep["signature"]["cache"] == "paged_ams"
        assert rep["signature"]["chunk"] == 4
        assert rep["served_ticks"] == s["ticks"]
        # read amplification: the ref paged gather reads whole pages, so
        # achieved KV traffic strictly exceeds the causal floor
        assert rep["kv_achieved_vs_floor"] > 1.0
        assert rep["kv_achieved_vs_floor"] == s["kv_achieved_vs_floor"]
        # floors accumulate: weights are re-read every tick at minimum
        cm = eng.cost_model
        assert rep["floor_hbm_bytes_total"] >= \
            rep["served_ticks"] * cm.weight_bytes
        assert rep["floor_flops_total"] > 0
        # per-request attribution landed on the Request objects
        assert all(r.kv_vs_floor > 1.0 for r in eng.finished)

    def test_hlo_cost_attribution(self, traced):
        """--hlo-cost path: lower + compile the live step and parse XLA's
        own cost — the achieved program must cost at least something and
        report a finite ratio vs the analytic floor."""
        eng, _, _ = traced
        rep = attribution(eng, hlo=True)
        assert rep["hlo_flops_per_tick"] > 0
        assert rep["hlo_hbm_bytes_per_tick"] > 0
        assert rep["hlo_hbm_vs_floor"] > 0


class TestObsConfig:
    def test_validation(self):
        with pytest.raises(ValueError):
            ObsConfig(jax_profile_ticks=-1)
        c = ObsConfig(enabled=False, trace=True, cost=True)
        assert not c.trace_on and not c.cost_on   # master switch wins

    def test_jax_profiler_capture_is_best_effort(self, tmp_path):
        """jax_profile_ticks=N wraps the first N device steps; a profiler
        that cannot start must disable itself, never crash serving."""
        eng = make_engine(obs=ObsConfig(jax_profile_ticks=1,
                                        jax_profile_dir=str(tmp_path)))
        reqs, _ = drive(eng, schedule()[:1])
        assert reqs[0].done
        assert eng._prof_ticks_left == 0 or not eng._prof_active
