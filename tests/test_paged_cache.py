"""Paged AMS-quantized KV-cache subsystem (`repro.cache`).

The two load-bearing properties from the subsystem's contract:

  (a) paged-bf16 greedy decode is TOKEN-IDENTICAL to the contiguous-slot
      engine across a mixed-length Poisson workload — paging is pure
      bookkeeping, the attended values are the same bits;
  (b) paged-AMS restores the EXACT lattice values a direct
      `quantize_kv`/`dequantize_kv` round trip produces (storage is
      bit-faithful), the Pallas kernel agrees with the `cache.ref`
      dequantize-then-attend oracle to f32-reduction tolerance, and
      `kv_bytes` reports >= 3.5x compression vs bf16 at production head
      dims.

Plus allocator/budget behaviour: admission is gated on the free-page pool,
pages are freed on completion, and strict FIFO holds under head-of-line
blocking.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import (
    CacheConfig,
    PageAllocator,
    compression_vs_bf16,
    gather_kv,
    make_gqa_page_pool,
    paged_attend,
    paged_attention_ref,
    paged_insert,
    paged_truncate,
)
from repro.core.kv_quant import dequantize_kv, kv_bytes, quantize_kv
from repro.launch.engine import ServeEngine
from repro.models.attention import kv_index_map

ARCH = "qwen2-7b"
SCHEME = "fp5.33-e2m3"
CAP = 32
PAGE = 8


def poisson_workload(n, seed=7, rate=0.5, prompt_mean=7, max_tokens=(4, 10)):
    """[(arrival_tick, prompt, max_tokens)] — mixed lengths, spread arrivals."""
    rng = np.random.default_rng(seed)
    gaps = rng.geometric(rate, n)
    arrivals = np.cumsum(gaps) - gaps[0]
    return [(int(t),
             rng.integers(0, 512, max(1, int(rng.poisson(prompt_mean)))),
             int(rng.integers(*max_tokens)))
            for t in arrivals]


def drive(eng, work):
    reqs, pending = [], list(work)
    while pending or eng.has_work:
        while pending and pending[0][0] <= eng.tick:
            _, prompt, mt = pending.pop(0)
            reqs.append(eng.submit(prompt, mt))
        eng.step()
    assert all(r.done for r in reqs)
    return reqs


# ---------------------------------------------------------------- allocator
def test_allocator_reserve_free():
    al = PageAllocator(num_pages=6, page_size=8)
    assert al.pages_needed(17) == 3 and al.pages_needed(16) == 2
    assert al.pages_needed(0) == 0
    p0, sh0 = al.alloc(0, 3)
    p1, _ = al.alloc(1, 2)
    assert sh0 == 0                       # cold pool: nothing shared
    assert len(set(p0) | set(p1)) == 5 and al.free_pages == 1
    assert not al.can_alloc(2)
    with pytest.raises(RuntimeError, match="exhausted"):
        al.alloc(2, 2)
    row = al.block_table_row(0, width=4)
    assert row.dtype == np.int32 and list(row[:3]) == p0 and row[3] == 0
    assert al.free(0) == 3
    assert al.free_pages == 4
    with pytest.raises(KeyError, match="double free"):
        al.free(0)          # double-free corrupts the free list: raise
    with pytest.raises(KeyError, match="unknown request"):
        al.free(99)         # unknown rid too


def test_cache_config_validation_and_sizing():
    with pytest.raises(ValueError, match="cache kind"):
        CacheConfig(kind="paged_int8")
    ccfg = CacheConfig(kind="paged-ams", page_size=8)   # dash normalizes
    assert ccfg.kind == "paged_ams" and ccfg.paged and ccfg.quantized
    sized = ccfg.sized(capacity=30, slots=3)
    assert sized.max_pages_per_seq == 4        # ceil(30 / 8)
    assert sized.num_pages == 12               # worst case for 3 slots
    assert not CacheConfig().paged


# ------------------------------------------------- (a) bf16 token identity
def test_paged_bf16_token_identical_to_contiguous():
    """Mixed-length Poisson workload on 2 slots (some requests queue): the
    paged-bf16 engine's greedy streams must equal the contiguous engine's
    bit for bit, request by request."""
    work = poisson_workload(5)
    base = ServeEngine(ARCH, scheme=SCHEME, slots=2, capacity=CAP, seed=0)
    paged = ServeEngine(ARCH, scheme=SCHEME, slots=2, capacity=CAP, seed=0,
                        cache_config=CacheConfig(kind="paged_bf16",
                                                 page_size=PAGE))
    r_base = drive(base, work)
    r_paged = drive(paged, work)
    assert paged.stats()["free_pages"] == paged.cache_cfg.num_pages
    for j, (a, b) in enumerate(zip(r_base, r_paged)):
        assert a.prompt.dtype == np.int32 and b.prompt.dtype == np.int32
        np.testing.assert_array_equal(
            np.asarray(a.tokens), np.asarray(b.tokens),
            err_msg=f"request {j}: paged-bf16 diverged from contiguous")


def test_paged_admission_by_page_budget():
    """Admission is gated on FREE PAGES, not slot count: with a 3-page pool
    (page=8), a 2-page request occupies the pool enough that the next
    2-page request waits even though a slot is free — and is admitted once
    the first completes and frees its pages."""
    ccfg = CacheConfig(kind="paged_bf16", page_size=8, num_pages=3)
    eng = ServeEngine(ARCH, scheme=SCHEME, slots=2, capacity=CAP, seed=0,
                      cache_config=ccfg)
    rng = np.random.default_rng(0)
    # kv_need = 8 + 3 - 1 = 10 -> 2 pages each
    r0 = eng.submit(rng.integers(0, 512, 8), 3)
    r1 = eng.submit(rng.integers(0, 512, 8), 3)
    eng.step()
    assert r0.admit_tick == 0 and len(r0.pages) == 2
    assert r1.admit_tick == -1          # slot free, but only 1 page free
    assert eng.alloc.free_pages == 1
    eng.run()
    assert r0.done and r1.done
    # freed pages turn into admission the SAME tick r0 finishes
    assert r1.admit_tick == r0.finish_tick
    assert eng.alloc.free_pages == 3


def test_submit_rejects_over_block_table():
    """Per-request ceiling in paged mode is the block-table width."""
    ccfg = CacheConfig(kind="paged_bf16", page_size=8, max_pages_per_seq=2)
    eng = ServeEngine(ARCH, scheme=SCHEME, slots=1, capacity=CAP, seed=0,
                      cache_config=ccfg)
    with pytest.raises(ValueError, match="cache positions"):
        eng.submit(np.arange(10), max_tokens=10)   # needs 19 > 2*8


# --------------------------------------------- (b) AMS lattice exactness
def _filled_pool(ccfg, B=2, kv=2, hd=32, lens=(13, 7), seed=0):
    rng = np.random.default_rng(seed)
    pool = make_gqa_page_pool(ccfg, kv, hd)
    mp = ccfg.max_pages_per_seq
    perm = rng.permutation(ccfg.num_pages)[: B * mp].reshape(B, mp)
    bt = jnp.asarray(perm.astype(np.int32))
    ks, vs = [], []
    for t in range(max(lens)):
        k_new = jnp.asarray(rng.standard_normal((B, 1, kv, hd)),
                            dtype=jnp.bfloat16)
        v_new = jnp.asarray(rng.standard_normal((B, 1, kv, hd)),
                            dtype=jnp.bfloat16)
        pos = jnp.asarray(np.where(t < np.asarray(lens), t, -1), jnp.int32)
        pool = paged_insert(pool, k_new, v_new, pos, bt, ccfg)
        ks.append(k_new)
        vs.append(v_new)
    k_hist = jnp.concatenate(ks, axis=1)   # [B, T, kv, hd]
    v_hist = jnp.concatenate(vs, axis=1)
    return pool, bt, jnp.asarray(np.asarray(lens), jnp.int32), k_hist, v_hist


def test_paged_ams_storage_is_lattice_exact():
    """Gathered+dequantized pages == a direct quantize/dequantize round trip
    of the inserted vectors, BIT FOR BIT, at every valid position."""
    ccfg = CacheConfig(kind="paged_ams", page_size=4).sized(capacity=16,
                                                            slots=2)
    pool, bt, lens, k_hist, v_hist = _filled_pool(ccfg, lens=(13, 7))
    kq, vq = gather_kv(pool, bt, 32, ccfg, dtype=jnp.float32)
    for hist, got in ((k_hist, kq), (v_hist, vq)):
        want = dequantize_kv(quantize_kv(hist), 32, dtype=jnp.float32)
        for b, ln in enumerate(np.asarray(lens)):
            np.testing.assert_array_equal(
                np.asarray(got[b, :ln]), np.asarray(want[b, :ln]))


@pytest.mark.slow
def test_paged_ams_pallas_matches_ref():
    """The Pallas kernel (interpret mode) walks the same block table and
    restores the same lattice values as the dequantize-then-attend oracle;
    outputs agree to f32 reduction tolerance, idle slots included."""
    ccfg = CacheConfig(kind="paged_ams", page_size=4).sized(capacity=16,
                                                            slots=3)
    B, kv, H, hd = 3, 2, 4, 32
    pool, bt, _, _, _ = _filled_pool(ccfg, B=B, kv=kv, hd=hd,
                                     lens=(13, 7, 1))
    lengths = jnp.asarray(np.array([13, 0, 1], np.int32))   # slot 1 idle
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((B, H, hd)), dtype=jnp.float32)
    kvm = kv_index_map(H, H, kv)
    o_ref = paged_attention_ref(q, pool, lengths, bt, ccfg, kv_map=kvm)
    o_pal = paged_attend(
        q, pool, lengths, bt,
        CacheConfig(kind="paged_ams", page_size=4,
                    impl="pallas_interpret").sized(capacity=16, slots=3),
        kv_map=kvm)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=2e-6, rtol=1e-6)
    assert np.all(np.asarray(o_pal[1]) == 0)   # idle slot: exact zeros


@pytest.mark.slow
def test_paged_ams_engine_pallas_interpret_end_to_end():
    """The full engine decodes through the Pallas kernel (interpret mode):
    workload completes, and a single tick from an identical cache state
    agrees with the ref impl's logits (small bf16-compounding tolerance)."""
    work = poisson_workload(3, max_tokens=(3, 5))
    eng = ServeEngine(ARCH, scheme=SCHEME, slots=2, capacity=16, seed=0,
                      cache_config=CacheConfig(kind="paged_ams", page_size=4,
                                               impl="pallas_interpret"))
    reqs = drive(eng, work)
    assert [len(r.tokens) for r in reqs] == [w[2] for w in work]


def test_paged_ams_engine_matches_ref_oracle():
    """Engine-level (b): greedy decode through the paged-AMS ref impl is
    deterministic and matches a fresh identical engine run token for token
    (the jitted step is a pure function of the packed pool state)."""
    work = poisson_workload(4, seed=11, max_tokens=(3, 6))
    ccfg = CacheConfig(kind="paged_ams", page_size=PAGE)
    r0 = drive(ServeEngine(ARCH, scheme=SCHEME, slots=2, capacity=CAP,
                           seed=0, cache_config=ccfg), work)
    r1 = drive(ServeEngine(ARCH, scheme=SCHEME, slots=2, capacity=CAP,
                           seed=0, cache_config=ccfg), work)
    for a, b in zip(r0, r1):
        np.testing.assert_array_equal(np.asarray(a.tokens),
                                      np.asarray(b.tokens))


# ------------------------------------------- truncate / rewind (speculative)
def _insert_hist(pool, bt, k_hist, v_hist, lens, ccfg, t0=0):
    """Insert history positions t0.. per sequence (masked past each len)."""
    for t in range(t0, k_hist.shape[1]):
        pos = jnp.asarray(np.where(t < lens, t, -1), jnp.int32)
        pool = paged_insert(pool, k_hist[:, t:t + 1], v_hist[:, t:t + 1],
                            pos, bt, ccfg)
    return pool


def test_paged_truncate_rewind_reinsert_lattice_exact():
    """The speculative-rollback contract: truncating the last m inserted
    positions restores the EXACT pool state before they were written (the
    packed planes, bit for bit), so rewind + re-insert of different tokens
    is indistinguishable from a straight insert — and the gathered pages
    stay lattice-exact vs the direct quantize/dequantize oracle the
    `cache/ref.py` path dequantizes through."""
    ccfg = CacheConfig(kind="paged_ams", page_size=4).sized(capacity=16,
                                                            slots=2)
    B, kv, hd, T = 2, 2, 32, 13
    lens = np.array([13, 7])
    count = np.array([5, 3])              # rewind m < C tokens per sequence
    start = lens - count
    rng = np.random.default_rng(5)
    bt = jnp.asarray(rng.permutation(ccfg.num_pages)[
        : B * ccfg.max_pages_per_seq].reshape(B, -1).astype(np.int32))
    mk = lambda: jnp.asarray(rng.standard_normal((B, T, kv, hd)),
                             dtype=jnp.bfloat16)
    kA, vA = mk(), mk()
    pool0 = make_gqa_page_pool(ccfg, kv, hd)
    poolA = _insert_hist(pool0, bt, kA, vA, lens, ccfg)

    poolT = paged_truncate(poolA, jnp.asarray(start, jnp.int32),
                           jnp.asarray(count, jnp.int32), bt, ccfg, c_max=5)
    # (1) truncation restores the exact prefix-only pool state
    poolP = _insert_hist(pool0, bt, kA, vA, start, ccfg)
    for got, want in zip(jax.tree.leaves(poolT), jax.tree.leaves(poolP)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # (2) re-insert DIFFERENT tokens at the rewound positions: bit-equal to
    # a straight insert of the combined history
    kB, vB = mk(), mk()
    kN = jnp.where((np.arange(T)[None, :, None, None] >= start[:, None, None, None]),
                   kB, kA)
    vN = jnp.where((np.arange(T)[None, :, None, None] >= start[:, None, None, None]),
                   vB, vA)
    poolR = _insert_hist(poolT, bt, kN, vN, lens, ccfg, t0=int(start.min()))
    poolS = _insert_hist(pool0, bt, kN, vN, lens, ccfg)
    for got, want in zip(jax.tree.leaves(poolR), jax.tree.leaves(poolS)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # (3) gathered + dequantized pages are lattice-exact vs the direct
    # round trip at every valid position
    kq, vq = gather_kv(poolR, bt, 16, ccfg, dtype=jnp.float32)
    for hist, got in ((kN, kq), (vN, vq)):
        want = dequantize_kv(quantize_kv(hist), 16, dtype=jnp.float32)
        for b, ln in enumerate(lens):
            np.testing.assert_array_equal(
                np.asarray(got[b, :ln]), np.asarray(want[b, :ln]))


def test_paged_truncate_zero_count_is_noop():
    ccfg = CacheConfig(kind="paged_ams", page_size=4).sized(capacity=16,
                                                            slots=2)
    pool, bt, lens, _, _ = _filled_pool(ccfg, lens=(13, 7))
    out = paged_truncate(pool, lens, jnp.zeros(2, jnp.int32), bt, ccfg,
                         c_max=4)
    for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(pool)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ----------------------------------------------------------- kv accounting
def test_kv_bytes_compression_over_3_5x():
    """>= 3.5x vs bf16 at production head dims (the fixed per-vector scale
    + LSB-word overhead only amortizes from hd=128 up), and the engine's
    accounting agrees with the layout formula."""
    for hd in (128, 256):
        packed, bf16 = kv_bytes(hd)
        assert bf16 / packed >= 3.5, (hd, packed, bf16)
    ccfg = CacheConfig(kind="paged_ams", page_size=PAGE)
    eng = ServeEngine(ARCH, scheme=SCHEME, slots=1, capacity=16, seed=0,
                      cache_config=ccfg)
    s = eng.stats()
    # reduced config: hd=32, kv=2, 2 layers; k+v packed = 2*kv*kv_bytes(32)
    packed32, bf16_32 = kv_bytes(32)
    assert s["kv_bytes_per_token"] == eng.cfg.num_layers * 2 * 2 * packed32
    assert s["kv_compression_vs_bf16"] == pytest.approx(bf16_32 / packed32)
    assert s["kv_compression_vs_bf16"] == pytest.approx(
        compression_vs_bf16(2, 32, ccfg))
