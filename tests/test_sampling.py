"""On-device sampling through the ragged engine step.

Pins the three contracts of `repro.launch.sampling`:

  * temperature=0 IS greedy — bit-identical to the argmax streams of the
    sampling-free engine across contiguous / paged_bf16 / paged_ams and
    chunk sizes, even with top_k/top_p set (ignored at temperature 0);
  * seeded stochastic streams replay bit-identically across engine
    restarts, slot counts (slot reassignment) and prefill chunking — the
    draw key folds in the request id and token index, never the slot;
  * in-step termination: a stop-token hit ends the request mid-stream,
    frees its pages (refcounts drain), admits the queue head the SAME
    tick, and stats() percentiles reflect the actual shorter lengths.

Plus numpy-reference unit tests of the top-k / top-p logit transforms.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import CacheConfig
from repro.launch.engine import ServeEngine
from repro.launch.sampling import (
    MAX_STOP_IDS,
    SamplingParams,
    _mask_top_k,
    _mask_top_p,
    _masked_logits,
    sample_tokens,
    slot_batch,
)

ARCH = "qwen2-7b"
SCHEME = "fp5.33-e2m3"
CAP = 32

CACHE_CFGS = {
    "contiguous": None,
    "paged_bf16": CacheConfig(kind="paged_bf16", page_size=8),
    "paged_ams": CacheConfig(kind="paged_ams", page_size=8),
}


def engine(mode="contiguous", slots=2, chunk=1):
    return ServeEngine(ARCH, scheme=SCHEME, slots=slots, capacity=CAP,
                       seed=0, prefill_chunk=chunk,
                       cache_config=CACHE_CFGS[mode])


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(11)
    return [rng.integers(0, 512, n) for n in (5, 9, 12)]


def run_all(eng, prompts, sampling):
    reqs = [eng.submit(p, 6, sampling=s)
            for p, s in zip(prompts, sampling)]
    eng.run()
    assert all(r.done for r in reqs)
    return [list(r.tokens) for r in reqs]


# ---------------------------------------------------------------------------
# transform unit tests (numpy reference)
# ---------------------------------------------------------------------------
def test_top_k_mask_matches_numpy():
    rng = np.random.default_rng(0)
    logits = rng.standard_normal(64).astype(np.float32)
    for k in (1, 3, 17, 64, 200):
        out = np.asarray(_mask_top_k(jnp.asarray(logits), jnp.int32(k)))
        kept = np.isfinite(out)
        thresh = np.sort(logits)[::-1][min(k, 64) - 1]
        np.testing.assert_array_equal(kept, logits >= thresh)
        np.testing.assert_array_equal(out[kept], logits[kept])
    # k = 0 disables
    out = np.asarray(_mask_top_k(jnp.asarray(logits), jnp.int32(0)))
    np.testing.assert_array_equal(out, logits)


def test_top_p_mask_matches_numpy():
    rng = np.random.default_rng(1)
    logits = rng.standard_normal(64).astype(np.float32)
    order = np.argsort(logits)[::-1]
    probs = np.exp(logits - logits.max())
    probs /= probs.sum()
    for p in (0.1, 0.5, 0.9):
        out = np.asarray(_mask_top_p(jnp.asarray(logits), jnp.float32(p)))
        kept = np.isfinite(out)
        csum = np.cumsum(probs[order])
        n_keep = int(np.sum((csum - probs[order]) < p))
        np.testing.assert_array_equal(
            kept, logits >= logits[order[n_keep - 1]],
            err_msg=f"top_p={p}")
    # the top token always survives, even at tiny p
    out = np.asarray(_mask_top_p(jnp.asarray(logits), jnp.float32(1e-6)))
    assert np.isfinite(out[np.argmax(logits)])
    assert np.sum(np.isfinite(out)) == 1
    # p = 1 disables
    out = np.asarray(_mask_top_p(jnp.asarray(logits), jnp.float32(1.0)))
    np.testing.assert_array_equal(out, logits)


def test_fused_mask_matches_reference_composition():
    """The hot path's single-sort fused mask == _mask_top_p(_mask_top_k)
    bit for bit, across enabled/disabled combinations and tie rows."""
    rng = np.random.default_rng(3)
    rows = [rng.standard_normal(64).astype(np.float32),
            np.zeros(64, np.float32),                       # all ties
            np.repeat(rng.standard_normal(16), 4).astype(np.float32)]
    for row in rows:
        x = jnp.asarray(row)
        for k in (0, 1, 5, 64):
            for p in (1.0, 0.9, 0.3, 1e-6):
                ref = _mask_top_p(_mask_top_k(x, jnp.int32(k)),
                                  jnp.float32(p))
                fused = _masked_logits(x, jnp.int32(k), jnp.float32(p))
                np.testing.assert_array_equal(
                    np.asarray(fused), np.asarray(ref),
                    err_msg=f"k={k} p={p}")


def test_sample_tokens_greedy_rows_are_argmax():
    """Mixed batch: temperature-0 rows must be EXACT argmax even with
    top_k/top_p set; sampled rows draw from the masked distribution."""
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.standard_normal((4, 32)).astype(np.float32))
    samp = slot_batch(4)
    samp["temperature"][:] = [0.0, 0.7, 0.0, 1.3]
    samp["top_k"][:] = 5
    samp["top_p"][:] = 0.9
    samp["key"][:] = np.asarray(jax.random.PRNGKey(3), np.uint32)
    samp["max_tokens"][:] = 100
    tok, done = jax.jit(sample_tokens)(
        logits, {k: jnp.asarray(v) for k, v in samp.items()})
    tok = np.asarray(tok)
    greedy = np.argmax(np.asarray(logits), axis=-1)
    np.testing.assert_array_equal(tok[[0, 2]], greedy[[0, 2]])
    # sampled rows stay inside the top-5 mask
    for b in (1, 3):
        top5 = np.sort(np.asarray(logits)[b])[::-1][4]
        assert np.asarray(logits)[b, tok[b]] >= top5
    assert not np.asarray(done).any()


def test_sample_tokens_done_flag():
    logits = jnp.zeros((3, 8), jnp.float32)
    samp = slot_batch(3)
    samp["max_tokens"][:] = [1, 5, 5]          # row 0 hits the length cap
    samp["stop_ids"][1, 0] = 0                 # row 1 stops on argmax token 0
    tok, done = sample_tokens(
        logits, {k: jnp.asarray(v) for k, v in samp.items()})
    np.testing.assert_array_equal(np.asarray(done), [True, True, False])


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError, match="stop_token_ids"):
        SamplingParams(stop_token_ids=(-3,))
    with pytest.raises(ValueError, match="max_tokens"):
        SamplingParams(max_tokens=0)
    with pytest.raises(ValueError, match="stop_token_ids"):
        SamplingParams(stop_token_ids=tuple(range(MAX_STOP_IDS + 1)))


# ---------------------------------------------------------------------------
# temperature=0 == greedy, across the cache-mode x chunk grid
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", list(CACHE_CFGS))
@pytest.mark.parametrize("chunk", [1, 4])
def test_temp0_pinned_to_greedy(mode, chunk, prompts):
    """Explicit SamplingParams(temperature=0, top_k/top_p set) streams are
    bit-identical to the default greedy path in every cache mode and chunk
    size — the sampling machinery must be invisible at temperature 0."""
    greedy = run_all(engine(mode, chunk=chunk), prompts, [None] * 3)
    explicit = run_all(
        engine(mode, chunk=chunk), prompts,
        [SamplingParams(temperature=0.0, top_k=5, top_p=0.5, seed=b)
         for b in range(3)])
    assert greedy == explicit


# ---------------------------------------------------------------------------
# seeded replay across restarts / slot reassignment / chunking
# ---------------------------------------------------------------------------
def test_seeded_replay_across_restarts_and_slots(prompts):
    """The same seeded top-p/top-k workload replays bit-identically on a
    fresh engine instance, with a different slot count (different slot
    assignment + tick interleaving) and different prefill chunking."""
    sp = [SamplingParams(temperature=0.8, top_k=40, top_p=0.9, seed=s)
          for s in (3, 3, 9)]   # two requests SHARE a seed: rid fold splits
    base = run_all(engine(slots=2), prompts, sp)
    assert base != run_all(engine(slots=2), prompts,
                           [None] * 3), "sampled != greedy sanity"
    # restart: fresh engine, same workload
    assert base == run_all(engine(slots=2), prompts, sp)
    # slot reassignment: serialized through one slot / all-parallel
    assert base == run_all(engine(slots=1), prompts, sp)
    assert base == run_all(engine(slots=3), prompts, sp)
    # ragged chunked prefill
    assert base == run_all(engine(slots=2, chunk=4), prompts, sp)
    # same-seed requests must still diverge (request id is folded in)
    assert base[0] != base[1]


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["paged_bf16", "paged_ams"])
def test_seeded_replay_paged(mode, prompts):
    sp = [SamplingParams(temperature=1.0, top_p=0.9, seed=s)
          for s in (1, 2, 3)]
    a = run_all(engine(mode, slots=2), prompts, sp)
    b = run_all(engine(mode, slots=1, chunk=4), prompts, sp)
    assert a == b


# ---------------------------------------------------------------------------
# early termination
# ---------------------------------------------------------------------------
def test_stop_token_ends_stream_and_frees_pages(prompts):
    """EOS mid-stream: the stream ends AT the stop token, the slot's pages
    free (refcounts drain to zero), a queued request admits the SAME tick,
    and stats() latency percentiles reflect the actual shorter lengths."""
    # greedy reference run picks the stop id: the 3rd generated token
    ref = run_all(engine("paged_ams", slots=1), prompts, [None] * 3)
    stop = ref[0][2]

    eng = engine("paged_ams", slots=1)
    r1 = eng.submit(prompts[0], sampling=SamplingParams(
        max_tokens=6, stop_token_ids=(stop,)))
    r2 = eng.submit(prompts[1], 6)
    eng.run()

    assert r1.tokens == ref[0][:3], "stream must end AT the stop token"
    assert r1.finish_reason == "stop" and r2.finish_reason == "length"
    # freed capacity became admission headroom the same tick
    assert r2.admit_tick == r1.finish_tick
    s = eng.stats()
    assert s["pages_in_use"] == 0, "refcounts must drain to zero"
    assert s["stopped_early"] == 1
    # latency/ttft percentiles come from ACTUAL lengths: r1 finished ~3
    # generated tokens earlier than its cap
    assert s["gen_tokens_mean"] == pytest.approx((3 + 6) / 2)
    assert r1.latency_ticks < r2.latency_ticks
    assert s["latency_ticks_p50"] <= s["latency_ticks_p99"]
    assert s["requests_finished"] == 2


def test_stop_token_in_contiguous_mode(prompts):
    ref = run_all(engine("contiguous", slots=1), prompts, [None] * 3)
    stop = ref[1][1]
    eng = engine("contiguous", slots=1)
    r = eng.submit(prompts[1], sampling=SamplingParams(
        max_tokens=6, stop_token_ids=(stop, 511)))
    eng.run()
    assert r.tokens == ref[1][:2] and r.finish_reason == "stop"


def test_max_tokens_resolution():
    eng = engine(slots=1)
    with pytest.raises(ValueError, match="max_tokens"):
        eng.submit(np.arange(4), sampling=SamplingParams(temperature=1.0))
    # SamplingParams.max_tokens wins over the positional cap
    r = eng.submit(np.arange(4), 99,
                   sampling=SamplingParams(max_tokens=2))
    eng.run()
    assert r.n_generated == 2 and r.finish_reason == "length"
