"""Refcounted prefix caching through the paged AMS KV cache.

The load-bearing contracts:

  (a) prefix caching is INVISIBLE in token space: caching-enabled engines
      produce greedy streams bit-identical to caching-disabled ones
      (paged_bf16 / paged_ams × chunk ∈ {1, 4}) on a shared-prefix
      workload — a cached page holds exactly the bytes a fresh prefill
      would write, because the pool's insert quantization is deterministic
      per (token, head);
  (b) it is VISIBLE in time: every request after the first starts prefill
      at the cached length, so prefill ticks and TTFT drop;
  (c) allocator refcount invariants hold under arbitrary alloc / free /
      publish / evict interleavings (hypothesis), and refcounts drain to
      zero when the engine drains.
"""

import numpy as np
import pytest

from repro.cache import CacheConfig, PageAllocator, prefix_page_hashes
from repro.launch.engine import ServeEngine
from repro.launch.sampling import SamplingParams

ARCH = "qwen2-7b"
SCHEME = "fp5.33-e2m3"
PAGE = 8
CAP = 32
PREFIX = 16   # shared system prompt: spans exactly two full pages


def shared_prefix_workload(n=4, seed=3, max_tokens=(3, 5)):
    """All requests share a PREFIX-token system prompt; arrivals after the
    first land once its prefill has published the shared pages (tick 18 >
    PREFIX), so every later request can hit the index."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, 512, PREFIX)
    work = []
    for i in range(n):
        suffix = rng.integers(0, 512, int(rng.integers(1, 6)))
        work.append((0 if i == 0 else 18 + i,
                     np.concatenate([sys_prompt, suffix]),
                     int(rng.integers(*max_tokens))))
    return work


def drive(eng, work):
    reqs, pending = [], list(work)
    while pending or eng.has_work:
        while pending and pending[0][0] <= eng.tick:
            _, prompt, mt = pending.pop(0)
            reqs.append(eng.submit(prompt, mt))
        eng.step()
    assert all(r.done for r in reqs)
    return reqs


def engine(kind, chunk=1, prefix_cache=True):
    return ServeEngine(ARCH, scheme=SCHEME, slots=2, capacity=CAP, seed=0,
                       prefill_chunk=chunk,
                       cache_config=CacheConfig(kind=kind, page_size=PAGE,
                                                prefix_cache=prefix_cache))


# ------------------------------------------- (a) + (b): stream equivalence
def _pinned_run(kind, chunk):
    work = shared_prefix_workload()
    on = engine(kind, chunk=chunk, prefix_cache=True)
    r_on = drive(on, work)
    r_off = drive(engine(kind, chunk=chunk, prefix_cache=False), work)
    for j, (a, b) in enumerate(zip(r_on, r_off)):
        np.testing.assert_array_equal(
            np.asarray(a.tokens), np.asarray(b.tokens),
            err_msg=f"{kind} C={chunk}: request {j} diverged under caching")
    # first request is cold; every later one skips the shared prefix
    assert r_on[0].cached_len == 0
    for a, b in zip(r_on[1:], r_off[1:]):
        assert a.cached_len == PREFIX
        pf_on, pf_off = a.prefill_ticks, b.prefill_ticks
        assert pf_on == -(-(a.prompt_len - PREFIX) // chunk)
        assert pf_on < pf_off and a.ttft_ticks < b.ttft_ticks
    # refcounts drained: nothing referenced once the engine is empty
    on.alloc.check_invariants()
    s = on.stats()
    assert s["pages_in_use"] == 0
    assert s["free_pages"] == on.cache_cfg.num_pages
    assert s["prefix_hit_pages"] == 2 * (len(work) - 1)   # 2 pages × 3 reqs
    # rate is over CACHEABLE pages only (2 per request; the cold request's
    # 2 are the only misses), so perfect warm reuse reads 6/8, not diluted
    # by generation-tail pages
    assert s["prefix_hit_rate"] == pytest.approx(6 / 8)
    assert s["cached_token_frac"] > 0


def test_prefix_cache_bit_identical_smoke():
    """Fast pin: paged-AMS × chunk 4 (the production shape)."""
    _pinned_run("paged_ams", 4)


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["paged_bf16", "paged_ams"])
@pytest.mark.parametrize("chunk", [1, 4])
def test_prefix_cache_bit_identical_grid(kind, chunk):
    """Full acceptance grid: paged_bf16 / paged_ams × chunk ∈ {1, 4}."""
    _pinned_run(kind, chunk)


def test_cache_aware_admission_charges_uncached_only():
    """A request whose prompt is fully cached (minus the last page) admits
    even when the pool only has room for its private tail."""
    # pool of 4 pages; prompts of 16 need kv_need=16+3-1=18 -> 3 pages
    ccfg = CacheConfig(kind="paged_bf16", page_size=8, num_pages=4)
    eng = ServeEngine(ARCH, scheme=SCHEME, slots=2, capacity=CAP, seed=0,
                      cache_config=ccfg)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 512, 16)
    r0 = eng.submit(prompt, 3)
    while not r0.done:
        eng.step()
    # r0's 2 full prompt pages are cached-evictable now; a sibling needs
    # 3 pages but only 1 uncached -> fits although only 2 are truly free
    assert eng.alloc.cached_pages == 2
    assert eng.stats()["pages_free_uncached"] == 2
    r1 = eng.submit(prompt, 3)
    eng.step()
    # cached_len is 8, not 16: the prompt ends ON a page boundary, and the
    # matchable prefix stops one position short of the end (the last prompt
    # token must be re-fed to produce the first generated token's logits)
    assert r1.admit_tick >= 0 and r1.cached_len == 8
    eng.run()
    np.testing.assert_array_equal(np.asarray(r0.tokens), np.asarray(r1.tokens))
    eng.alloc.check_invariants()


# ----------------------------------------------------- allocator unit tests
def _hashes(tokens, n=None):
    h = prefix_page_hashes(np.asarray(tokens), 4, "t")
    return h if n is None else h[:n]


def test_allocator_match_pin_reuse():
    al = PageAllocator(num_pages=4, page_size=4)
    hs = _hashes(np.arange(8))                      # 2 full pages
    p, shared = al.alloc(0, 3, hashes=hs)           # cold: all private
    assert shared == 0
    # misses count only the 2 CACHEABLE (hashed) pages, not the tail page
    assert al.match_prefix(hs) == 0 and al.misses == 2
    assert al.publish(0, hs[0], p[0]) and al.publish(0, hs[1], p[1])
    assert not al.publish(0, hs[0], p[2])           # hash resident: no-op
    assert al.match_prefix(hs) == 2
    al.free(0)
    assert al.free_pages == 4 and al.cached_pages == 2
    q, shared = al.alloc(1, 3, hashes=hs)           # warm: 2 shared + 1 priv
    assert shared == 2
    assert q[:2] == p[:2] and al.hits == 2
    assert al.cached_pages == 0                     # pinned out of the LRU
    al.free(1)
    al.check_invariants()


def test_allocator_refcount_sharing():
    """Two requests pin the same cached pages; the pages stay referenced
    until BOTH release, then return to the evictable LRU."""
    al = PageAllocator(num_pages=6, page_size=4)
    hs = _hashes(np.arange(8))
    p, _ = al.alloc(0, 2, hashes=hs)
    al.publish(0, hs[0], p[0])
    al.publish(0, hs[1], p[1])
    a, _ = al.alloc(1, 3, hashes=hs)
    b, _ = al.alloc(2, 3, hashes=hs)
    assert a[:2] == p[:2] == b[:2] and a[2] != b[2]
    al.free(0)
    al.free(1)
    assert al.cached_pages == 0                     # rid 2 still holds them
    al.check_invariants()
    al.free(2)
    assert al.cached_pages == 2 and al.free_pages == 6
    al.check_invariants()


def test_allocator_lru_eviction_order():
    """Under pressure, the least-recently-released cached page is evicted
    first and its hash leaves the index."""
    al = PageAllocator(num_pages=2, page_size=4)
    h_a, h_b = _hashes(np.arange(4)), _hashes(100 + np.arange(4))
    pa, _ = al.alloc(0, 1, hashes=h_a)
    al.publish(0, h_a[0], pa[0])
    pb, _ = al.alloc(1, 1, hashes=h_b)
    al.publish(1, h_b[0], pb[0])
    al.free(0)                                      # a released first (colder)
    al.free(1)
    assert al.cached_pages == 2 and al.free_pages == 2
    got, _ = al.alloc(2, 1)                         # no match -> evict a
    assert got == [pa[0]] and al.evictions == 1
    assert al.match_prefix(h_a) == 0 and al.match_prefix(h_b) == 1
    al.free(2)
    al.check_invariants()


def test_allocator_exhaustion_counts_pinned_lru():
    """Matched LRU pages are pinned, not spent: they can't double as the
    private-page supply in the same alloc."""
    al = PageAllocator(num_pages=2, page_size=4)
    hs = _hashes(np.arange(4))
    p, _ = al.alloc(0, 1, hashes=hs)
    al.publish(0, hs[0], p[0])
    al.free(0)
    assert not al.can_alloc(3, hashes=hs)           # 1 shared + 2 private > 2
    assert al.can_alloc(2, hashes=hs)
    with pytest.raises(RuntimeError, match="exhausted"):
        al.alloc(1, 3, hashes=hs)
    al.check_invariants()


def test_allocator_publish_guards():
    al = PageAllocator(num_pages=2, page_size=4)
    hs = _hashes(np.arange(4))
    al.alloc(0, 1)
    with pytest.raises(ValueError, match="does not own"):
        al.publish(0, hs[0], 1)                     # page 1 not rid 0's
    with pytest.raises(ValueError, match="does not own"):
        al.publish(7, hs[0], 0)                     # unknown rid
    al.free(0)
    al.check_invariants()


# --------------------------------------------- (c) property: random traffic
# The property test needs hypothesis (dev extras — see pyproject.toml);
# guard just it so the deterministic half of this module always runs.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*a, **k):                              # keep the def importable
        return lambda f: f

    settings = given
    st = None

# overlapping prompt pool: same first page / same two pages / disjoint, so
# random traffic actually exercises sharing, pinning, and eviction
_PROMPTS = [np.arange(12), np.concatenate([np.arange(8), 90 + np.arange(4)]),
            np.arange(12) + 40, np.arange(4)]


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=40, deadline=None)
@given(st.data() if HAVE_HYPOTHESIS else None)
def test_allocator_invariants_under_random_traffic(data):
    """Random alloc/publish/free interleavings: after every operation no
    page is both free and referenced, refcounts equal owner multiplicity,
    and on drain every refcount returns to zero."""
    al = PageAllocator(num_pages=data.draw(st.integers(3, 10), label="pages"),
                       page_size=4)
    live = {}
    next_rid = 0
    for _ in range(data.draw(st.integers(1, 30), label="ops")):
        op = data.draw(st.sampled_from(["alloc", "alloc", "free", "publish"]))
        if op == "alloc":
            hs = _hashes(data.draw(st.sampled_from(_PROMPTS)))
            n = data.draw(st.integers(1, 4))
            hs = hs[:n]
            if al.can_alloc(n, hashes=hs):
                pages, shared = al.alloc(next_rid, n, hashes=hs)
                live[next_rid] = (pages, hs, shared)
                next_rid += 1
            else:
                with pytest.raises(RuntimeError):
                    al.alloc(next_rid, n, hashes=hs)
        elif op == "free" and live:
            rid = data.draw(st.sampled_from(sorted(live)))
            live.pop(rid)
            al.free(rid)
            with pytest.raises(KeyError):
                al.free(rid)                         # double free always raises
        elif op == "publish" and live:
            rid = data.draw(st.sampled_from(sorted(live)))
            pages, hs, shared = live[rid]
            if shared < len(hs):                     # only private pages
                al.publish(rid, hs[shared], pages[shared])
        al.check_invariants()
    for rid in sorted(live):
        al.free(rid)
        al.check_invariants()
    assert al.free_pages == al.num_pages
    assert al.stats()["pages_in_use"] == 0


def test_allocator_invariants_seeded_traffic():
    """Deterministic mirror of the hypothesis property (always runs, even
    without hypothesis installed): 200 seeded random ops, invariants
    checked after each, refcounts drain to zero."""
    rng = np.random.default_rng(17)
    al = PageAllocator(num_pages=6, page_size=4)
    live = {}
    next_rid = 0
    for _ in range(200):
        op = rng.choice(["alloc", "alloc", "free", "publish"])
        if op == "alloc":
            hs = _hashes(_PROMPTS[rng.integers(len(_PROMPTS))])
            n = int(rng.integers(1, 5))
            hs = hs[:n]
            if al.can_alloc(n, hashes=hs):
                pages, shared = al.alloc(next_rid, n, hashes=hs)
                live[next_rid] = (pages, hs, shared)
                next_rid += 1
        elif op == "free" and live:
            rid = sorted(live)[rng.integers(len(live))]
            live.pop(rid)
            al.free(rid)
        elif op == "publish" and live:
            rid = sorted(live)[rng.integers(len(live))]
            pages, hs, shared = live[rid]
            if shared < len(hs):
                al.publish(rid, hs[shared], pages[shared])
        al.check_invariants()
    for rid in sorted(live):
        al.free(rid)
    al.check_invariants()
    assert al.free_pages == al.num_pages
    assert al.stats()["pages_in_use"] == 0
    assert al.evictions > 0          # seeded traffic really hit pressure


# ---------------------------------- combined stress: everything at once
def test_stress_spec_rollback_stops_prefixes_page_pressure():
    """Seeded random traffic combining every serving feature at once:
    shared prefixes (prefix cache hits), stop tokens (early termination),
    per-request sampling, page pressure (head-of-line blocking on the
    free-page budget) — all through a SPECULATIVE engine whose n-gram
    drafter keeps landing rollbacks. After the drain: every refcount is
    zero, no page was double-freed (allocator raises on the spot), and the
    greedy requests' streams equal a non-speculative engine's bit for bit."""
    rng = np.random.default_rng(23)
    # repetitive system prompt: guarantees the n-gram drafter proposes
    # (and therefore that rollbacks actually land)
    sys_prompt = np.tile(rng.integers(0, 512, 4), 4)
    work = []
    t = 0
    for i in range(8):
        t += int(rng.integers(0, 9))
        suffix = rng.integers(0, 512, int(rng.integers(1, 6)))
        prompt = (np.concatenate([sys_prompt, suffix]) if i % 2 == 0
                  else suffix)
        sp = None
        if i % 4 == 1:          # sampled + stop tokens
            sp = SamplingParams(temperature=0.8, top_p=0.9, seed=100 + i,
                                stop_token_ids=tuple(
                                    rng.integers(0, 512, 3).tolist()))
        elif i % 4 == 3:        # greedy + stop tokens
            sp = SamplingParams(stop_token_ids=tuple(
                rng.integers(0, 512, 3).tolist()))
        work.append((t, prompt, int(rng.integers(3, 7)), sp))

    def run(speculate_k):
        # 8-page pool: two worst-case requests exhaust it, so admission
        # really blocks on the free-page budget mid-run
        eng = ServeEngine(ARCH, scheme=SCHEME, slots=2, capacity=CAP, seed=0,
                          prefill_chunk=2, speculate_k=speculate_k,
                          drafter="ngram",
                          cache_config=CacheConfig(kind="paged_ams",
                                                   page_size=PAGE,
                                                   num_pages=8))
        reqs, pending = [], list(work)
        while pending or eng.has_work:
            while pending and pending[0][0] <= eng.tick:
                _, prompt, mt, sp = pending.pop(0)
                reqs.append(eng.submit(prompt, mt, sampling=sp))
            eng.step()
        assert all(r.done for r in reqs)
        return eng, reqs

    eng, reqs = run(speculate_k=2)
    s = eng.stats()
    assert s["spec_proposed"] > 0                  # drafting really happened
    assert s["prefix_hit_pages"] > 0               # prefix cache really hit
    # refcounts drained to zero, nothing double-freed, invariants hold
    eng.alloc.check_invariants()
    assert s["pages_in_use"] == 0
    assert s["free_pages"] == 8           # cached-evictable pages count free
    # greedy requests are bit-identical to the non-speculative engine
    # (sampled requests follow the same law but consume draws differently)
    base, base_reqs = run(speculate_k=0)
    base.alloc.check_invariants()
    n_greedy = 0
    for j, (a, b) in enumerate(zip(reqs, base_reqs)):
        if a.sampling.temperature == 0:
            n_greedy += 1
            np.testing.assert_array_equal(
                np.asarray(a.tokens), np.asarray(b.tokens),
                err_msg=f"request {j} diverged under speculation")
            assert a.finish_reason == b.finish_reason
    assert n_greedy >= 4
