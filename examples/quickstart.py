"""Quickstart: AMS-Quant in 30 lines.

Quantize a weight matrix to FP5.33 (e2m3, 3 weights sharing each mantissa
LSB), inspect the storage saving, and run the packed matmul three ways:
reference, K-blocked fused, and the Pallas TPU kernel (interpret mode on
CPU). Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import get_scheme, quantize_linear
from repro.core.qlinear import apply as qapply, dequantize_weight
from repro.kernels import ops, ref

rng = np.random.default_rng(0)
K, N, B = 1536, 512, 4
w = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32) * 0.02)
x = jnp.asarray(rng.standard_normal((B, K)).astype(np.float32))

scheme = get_scheme("fp5.33-e2m3")
print(f"scheme: {scheme.name}  effective bits/weight: {scheme.effective_bits:.3f}")

q = quantize_linear(w, scheme, strategy="set_lsb")
lay = q.packed.layout
print(f"container: {lay.container}  packed bytes: {lay.packed_bytes(K, N):,} "
      f"(fp16 would be {2*K*N:,}; {2*K*N/lay.packed_bytes(K,N):.2f}x smaller)")

wq = dequantize_weight(q, jnp.float32)
print(f"quantization MSE: {float(jnp.mean((wq - w)**2)):.3e}")

y_ref = qapply(q, x, impl="ref")
y_fused = qapply(q, x, impl="fused_ref")
y_pallas = ops.ams_matmul(x, q.packed, interpret=True)
print("ref vs fused   max err:", float(jnp.max(jnp.abs(y_ref - y_fused))))
print("ref vs pallas  max err:", float(jnp.max(jnp.abs(y_ref - y_pallas))),
      "(bf16 activation rounding in the MXU path)")
