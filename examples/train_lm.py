"""End-to-end driver: train a ~10M-param qwen2-family model for a few hundred
steps on the synthetic corpus, with checkpointing + fault tolerance.

This is the (b)-deliverable end-to-end training example; the same driver
runs production meshes with --mesh single/multi on real pods.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart_ckpt")
    args = ap.parse_args()
    losses = train_main([
        "--arch", "qwen2-7b", "--reduced",
        "--steps", str(args.steps),
        "--seq-len", "128", "--global-batch", "8",
        "--lr", "2e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--log-every", "20",
    ])
    assert losses[-1] < losses[0] - 1.0, "training did not learn"
    print("OK: loss improved", losses[0], "->", losses[-1])
