"""Serve a model with AMS-Quant PTQ: train briefly, quantize to FP5.33 /
FP4.25, and compare generations + decode latency against the fp16 baseline.

Demonstrates the paper's deployment path end to end: ahead-of-time packing
-> prefill -> batched decode with on-the-fly bit restoration.

Run:  PYTHONPATH=src python examples/quantize_and_serve.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.launch.serve import generate
from repro.launch.train import main as train_main
from repro.models import init_params
from repro.optim import init_state

CKPT = "/tmp/repro_serve_demo_ckpt"

# 1) get a (briefly) trained model so generations are non-degenerate
train_main(["--arch", "qwen1.5-4b", "--reduced", "--steps", "120",
            "--seq-len", "128", "--global-batch", "8", "--lr", "2e-3",
            "--ckpt-dir", CKPT, "--ckpt-every", "120", "--log-every", "40"])
cfg = get_config("qwen1.5-4b").reduced()
params = init_params(jax.random.PRNGKey(0), cfg)
restored, _ = CheckpointManager(CKPT).restore(
    {"params": params, "opt": init_state(params)})
params = jax.tree.map(jnp.asarray, restored["params"])

# 2) serve fp16 vs AMS-quantized
results = {}
for scheme in ("fp16", "fp5.33-e2m3", "fp4.25-e2m2"):
    toks, stats = generate("qwen1.5-4b", reduced=True, scheme=scheme,
                           params=params, batch=2, prompt_len=24,
                           gen_tokens=24, seed=3)
    results[scheme] = toks
    print(f"{scheme:14s} decode median {stats['decode_ms_median']:.1f} ms "
          f"(CPU; memory-bound speedup needs accelerator BW)")

for scheme in ("fp5.33-e2m3", "fp4.25-e2m2"):
    match = (results[scheme] == results["fp16"]).mean()
    print(f"token match vs fp16 [{scheme}]: {100*match:.1f}%")
