"""Continuous-batching serving in ~40 lines.

Quantize a model to FP5.33 ahead of time, stand up the slot-based engine,
and stream requests at it MID-FLIGHT: a long request decodes while shorter
ones arrive, queue, get admitted into freed slots, and finish — all through
one jitted slot-masked decode step. Each request's greedy output is
identical to running it alone (batch invariance; see tests/test_engine.py).

Run:  PYTHONPATH=src python examples/serve_continuous.py
"""

import numpy as np

from repro.launch.engine import ServeEngine

rng = np.random.default_rng(0)

eng = ServeEngine("qwen2-7b", reduced=True, scheme="fp5.33-e2m3",
                  slots=2, capacity=48, seed=0, verbose=True)

# arrival schedule: tick -> (prompt_len, max_tokens). Two slots, four
# requests: r2/r3 must queue until r0/r1 free their slots.
schedule = {0: [(6, 16)], 1: [(10, 8)], 4: [(4, 12)], 6: [(8, 6)]}

requests = []
while eng.has_work or eng.tick <= max(schedule):
    for plen, mt in schedule.get(eng.tick, []):
        req = eng.submit(rng.integers(0, eng.cfg.vocab_size, plen), mt)
        requests.append(req)
        print(f"tick {eng.tick:3d} | submit  r{req.rid} "
              f"(prompt {plen}, want {mt} tokens) queue={eng.sched.queue_depth}")
    info = eng.step()
    for req in info["finished"]:
        print(f"tick {eng.tick - 1:3d} | finish  r{req.rid} slot {req.slot} "
              f"(admitted t{req.admit_tick}): {req.tokens}")

stats = eng.stats()
print(f"\n{len(requests)} requests in {stats['ticks']} ticks | "
      f"{stats['tokens_generated']} tokens @ {stats['tokens_per_s']:.1f} tok/s "
      f"| p50 {stats['decode_ms_median']:.1f} ms "
      f"p99 {stats['decode_ms_p99']:.1f} ms per token")
