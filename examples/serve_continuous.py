"""Continuous-batching serving in ~40 lines.

Quantize a model to FP5.33 ahead of time, stand up the slot-based engine
over a PAGED, AMS-quantized KV cache (each inserted K/V vector packed to
e2m2 planes once at insert; see docs/paged_cache.md), and stream requests
at it MID-FLIGHT: a long request decodes while shorter ones arrive, queue,
get admitted into freed page budget, and finish — all through one jitted
slot-masked decode step. Pass ``--contiguous`` for the PR-1 fixed-slot
cache (each request's greedy output is then identical to running it alone;
batch invariance, see tests/test_engine.py).

Run:  PYTHONPATH=src python examples/serve_continuous.py [--contiguous]
"""

import sys

import numpy as np

from repro.cache import CacheConfig
from repro.launch.engine import ServeEngine

rng = np.random.default_rng(0)

cache_config = (None if "--contiguous" in sys.argv[1:] else
                CacheConfig(kind="paged_ams", page_size=16))
eng = ServeEngine("qwen2-7b", reduced=True, scheme="fp5.33-e2m3",
                  slots=2, capacity=48, seed=0, verbose=True,
                  cache_config=cache_config)

# arrival schedule: tick -> (prompt_len, max_tokens). Two slots, four
# requests: r2/r3 must queue until r0/r1 free their slots.
schedule = {0: [(6, 16)], 1: [(10, 8)], 4: [(4, 12)], 6: [(8, 6)]}

requests = []
while eng.has_work or eng.tick <= max(schedule):
    for plen, mt in schedule.get(eng.tick, []):
        req = eng.submit(rng.integers(0, eng.cfg.vocab_size, plen), mt)
        requests.append(req)
        print(f"tick {eng.tick:3d} | submit  r{req.rid} "
              f"(prompt {plen}, want {mt} tokens) queue={eng.sched.queue_depth}")
    info = eng.step()
    for req in info["finished"]:
        print(f"tick {eng.tick - 1:3d} | finish  r{req.rid} slot {req.slot} "
              f"(admitted t{req.admit_tick}): {req.tokens}")

stats = eng.stats()
print(f"\n{len(requests)} requests in {stats['ticks']} ticks | "
      f"{stats['tokens_generated']} tokens @ {stats['tokens_per_s']:.1f} tok/s "
      f"| p50 {stats['decode_ms_median']:.1f} ms "
      f"p99 {stats['decode_ms_p99']:.1f} ms per token")
print(f"kv cache: {eng.cache_cfg.kind} | "
      f"{stats['kv_bytes_per_token']} B/token | "
      f"{stats['kv_compression_vs_bf16']:.2f}x vs bf16"
      + (f" | {stats['free_pages']} pages free" if "free_pages" in stats else ""))
