"""Continuous-batching serving with a shared system prompt, in ~60 lines.

Quantize a model to FP5.33 ahead of time, stand up the slot-based engine
over a PAGED, AMS-quantized KV cache (each inserted K/V vector packed to
e2m2 planes once at insert; see docs/paged_cache.md), and stream requests
at it MID-FLIGHT. Every request shares the same 16-token system prompt, so
with PREFIX CACHING (on by default) the shared pages prefill and quantize
ONCE: request 0 pays the full prefill, every later request pins the cached
pages (refcount += 1) and starts at the cached length. The same workload
runs again with ``CacheConfig(prefix_cache=False)`` to show the measured
TTFT and hit-rate delta — token streams are bit-identical either way.

Requests carry PER-REQUEST SamplingParams (see docs/sampling.md): r0 and
r3 decode greedily, r1 samples at temperature 0.9 / top-p 0.9, and r2
samples with STOP TOKENS — it terminates mid-stream the moment one is
drawn (finish_reason "stop"), freeing its pages and admission headroom
the same tick. Sampled streams are seeded per request and replay
bit-identically across both runs, so the cached-vs-cold token assert
still holds.

Pass ``--contiguous`` for the PR-1 fixed-slot cache (no paging, no prefix
cache; each request's greedy output is then identical to running it alone;
batch invariance, see tests/test_engine.py).

The script ends with a SPECULATIVE re-run of a small greedy workload
(``speculate_k=4``, the full-stack self-drafter; see docs/speculative.md):
each round drafts up to 4 tokens and verifies them all in ONE ragged
engine step, so a round emits up to 5 tokens per model pass. The demo
asserts the token streams are identical to the non-speculative run and
prints the measured accept rate, tokens per emitting round, and tick
savings.

The first run prints a live one-line-per-tick TICKER read straight off the
engine's metrics registry (``repro.obs``; see docs/observability.md):
active slots, queue depth, prefix-cache hit rate, speculative accept rate,
KV bytes/token and achieved-vs-floor HBM traffic.

Run:  PYTHONPATH=src python examples/serve_continuous.py [--contiguous]
"""

import sys

import numpy as np

from repro.obs import ticker_line
from repro.serving import (CacheConfig, EngineConfig, SamplingParams,
                           ServeEngine)

SYS_LEN = 16          # shared system prompt: two full 8-token pages
PAGED = "--contiguous" not in sys.argv[1:]

# arrival schedule: tick -> (suffix_len, SamplingParams). Request 0
# arrives alone so its prefill publishes the shared pages before the
# burst at tick 20+ (two slots: r3 must also queue for a free slot).
# r1 samples stochastically; r2 carries stop tokens and ends early.
SCHEDULE = {
    0: [(6, SamplingParams(max_tokens=16))],                      # greedy
    20: [(10, SamplingParams(temperature=0.9, top_p=0.9, seed=11,
                             max_tokens=8))],
    22: [(4, SamplingParams(temperature=0.9, top_k=64, seed=5,
                            max_tokens=12,
                            stop_token_ids=(402, 509, 263)))],
    24: [(8, SamplingParams(max_tokens=6))],                      # greedy
}


def drive(prefix_cache: bool, ticker: bool = False):
    cache_config = (CacheConfig(kind="paged_ams", page_size=8,
                                prefix_cache=prefix_cache)
                    if PAGED else None)
    eng = ServeEngine(EngineConfig(slots=2, capacity=48, verbose=True,
                                   cache=cache_config))
    rng = np.random.default_rng(0)   # fresh rng: identical prompts per run
    sys_prompt = rng.integers(0, eng.cfg.vocab_size, SYS_LEN)
    requests = []
    while eng.has_work or eng.tick <= max(SCHEDULE):
        for slen, sp in SCHEDULE.get(eng.tick, []):
            prompt = np.concatenate(
                [sys_prompt, rng.integers(0, eng.cfg.vocab_size, slen)])
            req = eng.submit(prompt, sampling=sp)
            requests.append(req)
            print(f"tick {eng.tick:3d} | submit  r{req.rid} "
                  f"(prompt {len(prompt)}, cap {sp.max_tokens}, "
                  f"T={sp.temperature:g}"
                  + (f", {len(sp.stop_token_ids)} stop ids" if
                     sp.stop_token_ids else "")
                  + f") queue={eng.sched.queue_depth}")
        info = eng.step()
        if ticker and info["active"]:
            # live telemetry read straight off the metrics registry
            # (repro.obs): active slots, queue depth, prefix hit rate,
            # speculative accept rate, KV bytes/token and achieved HBM
            # traffic vs the analytic roofline floor
            print(ticker_line(eng))
        for req in info["finished"]:
            print(f"tick {eng.tick - 1:3d} | finish  r{req.rid} "
                  f"slot {req.slot} (admitted t{req.admit_tick}, "
                  f"{req.cached_len} positions from cache, "
                  f"{req.finish_reason}): {req.tokens}")
    return requests, eng


requests, eng = drive(prefix_cache=True, ticker=True)
stats = eng.stats()
print(f"\n{len(requests)} requests in {stats['ticks']} ticks | "
      f"{stats['tokens_generated']} tokens @ {stats['tokens_per_s']:.1f} tok/s "
      f"| p50 {stats['decode_ms_median']:.1f} ms "
      f"p99 {stats['decode_ms_p99']:.1f} ms per token")
print(f"kv cache: {eng.cache_cfg.kind} | "
      f"{stats['kv_bytes_per_token']} B/token | "
      f"{stats['kv_compression_vs_bf16']:.2f}x vs bf16"
      + (f" | {stats['free_pages']} pages free" if "free_pages" in stats else ""))

if PAGED:
    # same workload, caching off: the measured prefix-cache win
    base_reqs, _ = drive(prefix_cache=False)
    print(f"\nprefix cache: hit rate {stats['prefix_hit_rate']:.0%}, "
          f"{stats['cached_token_frac']:.0%} of prompt positions served "
          f"from shared pages")
    print("  req   ttft(cached)   ttft(cold)   prefill skipped")
    for r, b in zip(requests, base_reqs):
        assert r.tokens == b.tokens, \
            "caching must not change tokens (greedy OR seeded sampling)"
        print(f"  r{r.rid}   {r.ttft_ticks:12d}   {b.ttft_ticks:10d}   "
              f"{r.cached_len:15d}")
    mean = float(np.mean([r.ttft_ticks for r in requests]))
    mean_b = float(np.mean([b.ttft_ticks for b in base_reqs]))
    print(f"mean TTFT {mean:.1f} vs {mean_b:.1f} ticks "
          f"({mean_b - mean:+.1f} saved by prefix caching; "
          f"token streams bit-identical)")


def drive_spec(speculate_k: int):
    """Small all-greedy workload for the speculative comparison: three
    requests over a shared system prompt, same cache mode as above."""
    cache_config = (CacheConfig(kind="paged_ams", page_size=8)
                    if PAGED else None)
    eng = ServeEngine(EngineConfig(slots=2, capacity=48,
                                   speculate_k=speculate_k,
                                   drafter="self-full", cache=cache_config))
    rng = np.random.default_rng(7)   # fresh rng: identical prompts per run
    sys_prompt = rng.integers(0, eng.cfg.vocab_size, SYS_LEN)
    reqs = []
    for slen in (5, 9, 7):
        prompt = np.concatenate(
            [sys_prompt, rng.integers(0, eng.cfg.vocab_size, slen)])
        reqs.append(eng.submit(prompt, sampling=SamplingParams(max_tokens=10)))
    eng.run()
    return reqs, eng.stats()


# speculative decoding: the model's own full stack drafts k=4 tokens per
# decoding slot each round; ONE ragged engine step scores all of them and
# accepts the longest prefix matching the running argmax, so greedy tokens
# cannot change — only how many arrive per round (docs/speculative.md)
base_reqs, base_stats = drive_spec(speculate_k=0)
spec_reqs, spec_stats = drive_spec(speculate_k=4)
for r, b in zip(spec_reqs, base_reqs):
    assert r.tokens == b.tokens, \
        "speculation must not change greedy token streams"
print(f"\nspeculative (k=4, self-full drafter) vs plain decode, "
      f"{len(spec_reqs)} greedy requests:")
print(f"  accept rate {spec_stats['accept_rate']:.0%} | "
      f"{spec_stats['tokens_per_step']:.2f} tokens per emitting round "
      f"(plain: {base_stats['tokens_per_step']:.2f})")
print(f"  engine ticks {base_stats['ticks']} -> {spec_stats['ticks']} "
      f"({base_stats['ticks'] - spec_stats['ticks']} saved; "
      f"token streams bit-identical)")
