"""Example: compile one (arch x shape) cell on the 2-pod 512-chip mesh and
print its memory/roofline summary. This is the per-cell entry point the full
sweep (python -m repro.launch.dryrun --mesh both) iterates.

Run:  PYTHONPATH=src python examples/multi_pod_dryrun.py \
          [--arch qwen2-7b] [--shape decode_32k]
"""

# NOTE: must run as its own process; dryrun pins 512 host devices pre-import.
import argparse
import subprocess
import sys

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--shape", default="decode_32k")
    args = ap.parse_args()
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", args.arch, "--shape", args.shape, "--mesh", "multi"]))
