"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (and tees a copy into
experiments/bench_results.txt):

    Table 2 / Fig.3 / Fig.5  -> bench_formats_accuracy (CE + weight-MSE proxy)
    §3.1 Adaptive Searching  -> bench_adaptive_search
    Table 3 / Fig.6          -> bench_kernel_speedup (analytic Table-3 model
                                + CPU wall-clock plumbing check; the
                                ``kernel_attn/`` rows compare fused-template
                                vs ref achieved KV bytes per cache scheme
                                and hard-assert the fused path never
                                materializes dequantized pages in HBM)
    Serving (beyond-paper)   -> bench_serving (fp16 vs AMS engine throughput
                                under one Poisson workload: contiguous,
                                paged, chunked-prefill, shared-prefix
                                (prefix-cache hit rate / cached-token
                                fraction), sampled (per-request
                                temperature/top-p + stop tokens) and
                                speculative (k-draft verify; accept_rate /
                                tokens_per_step columns) rows in the
                                same CSV)
    §Roofline summary        -> bench_roofline (reads experiments/dryrun)

Run: PYTHONPATH=src python -m benchmarks.run [--quick]

ARTIFACTS (uploaded by the CI bench job with ``if: always()``):
    experiments/bench_results.txt       — every CSV row of the sweep
    experiments/serving_trace-*.json    — Perfetto-loadable chrome trace of
                                          the shared-prefix + speculative
                                          serving row, per scheme (load at
                                          ui.perfetto.dev; see
                                          docs/observability.md)
    experiments/serving_trace-*.prom    — Prometheus text-format snapshot of
                                          the same run's metrics registry
The paged serving row additionally re-runs itself with observability
disabled and asserts 0% perturbation of the deterministic tick/stream
metrics (``--obs-check``), so telemetry can never silently invalidate the
committed baseline.

REGRESSION GATE (``--check benchmarks/baseline.csv``): after the sweep,
the serving rows are compared against a committed baseline and the run
exits non-zero on a >15% regression in any deterministic serving metric —
engine ticks to drain the fixed workload (the decode-tick throughput
measure), TTFT / latency tick percentiles, or kv-bytes-per-token. These
are exact given ``--seed``, so ANY drift is a real behaviour change, not
runner noise. Wall-clock-derived numbers (tokens/s, ms percentiles, the
``x=`` speedup ratio) are NOT gated — they do not transfer across
machines, and the --quick workload is too small to time reliably even as
a ratio. A decode-throughput regression still trips the gate as extra
engine ticks on the fixed workload. Regenerate the baseline after an
intentional change with ``--write-baseline benchmarks/baseline.csv``.
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def bench_roofline(out_lines):
    """Summarize dry-run roofline terms if dry-run records exist."""
    try:
        from repro.analysis.roofline import analyze, load_records
    except Exception as e:  # pragma: no cover
        print(f"roofline/skip,0,import_error={e!r}")
        return
    recs = load_records("experiments/dryrun", "pod256")
    if not recs:
        line = "roofline/skip,0,no dry-run records (run repro.launch.dryrun)"
        print(line)
        out_lines.append(line)
        return
    for r in recs:
        a = analyze(r)
        line = (f"roofline/{r['arch']}/{r['shape']},0,"
                f"dom={a['dominant']} compute_s={a['compute_s']:.4g} "
                f"memory_s={a['memory_s']:.4g} "
                f"collective_s={a['collective_s']:.4g} "
                f"useful={a['useful_flops_ratio']} "
                f"roofline_frac={a['roofline_fraction']}")
        print(line, flush=True)
        out_lines.append(line)


# --------------------------------------------------------------------------
# bench regression gate
# --------------------------------------------------------------------------
# deterministic serving metrics (exact given the workload seed): any move
# past the tolerance is a real scheduling/termination/layout change
GATED = {
    "ticks": ("higher", 0.15),
    "ttft_ticks_p50": ("higher", 0.15),
    "ttft_ticks_p99": ("higher", 0.15),
    "latency_ticks_p50": ("higher", 0.15),
    "latency_ticks_p99": ("higher", 0.15),
    "kv_bytes_per_token": ("higher", 0.15),
    # speculative decoding: both are exact given the seed (deterministic
    # drafters, greedy verify); fewer accepted drafts or fewer tokens per
    # emitting round is a real speculation regression
    "accept_rate": ("lower", 0.15),
    "tokens_per_step": ("lower", 0.15),
    # preemption (serving/overload row): scheduling decisions are exact
    # given the seed — more preemptions is scheduler thrash, and a LOWER
    # count here means the priority policy stopped firing (the row's
    # in-run assert additionally pins preemptive p99 TTFT < head-of-line)
    "preemptions": ("higher", 0.15),
    "resumes": ("higher", 0.15),
    # kernel_attn rows (fused template vs ref, StepCostModel accounting —
    # exact analytic bytes): more achieved bytes per causal-floor byte is a
    # lowering regression, and ANY dequant_kb on a fused row means packed
    # pages got re-materialized in HBM (baseline pins it at 0)
    "kv_vs_floor": ("higher", 0.15),
    "dequant_kb": ("higher", 0.15),
    # NOT gated: anything wall-clock-derived. Even the AMS/fp16 speedup
    # ratio x (machine speed divides out) swings >2x between modes of one
    # --quick run on CPU — the workload is far too small to time reliably.
    # Decode-throughput regressions still show: a slower schedule = more
    # engine ticks to drain the same fixed workload.
}


GATED_PREFIXES = ("serving/", "kernel_attn/")


def parse_rows(lines):
    """'name,us_per_call,k=v k=v ...' -> {name: {k: float}} (gated rows:
    serving + the fused-attention accounting rows)."""
    rows = {}
    for ln in lines:
        ln = ln.strip()
        if not ln or ln.startswith("#") \
                or not ln.startswith(GATED_PREFIXES):
            continue
        name, _, rest = ln.split(",", 2)
        fields = {}
        for part in rest.split():
            key, sep, val = part.partition("=")
            if sep:
                try:
                    fields[key] = float(val)
                except ValueError:
                    pass
        rows[name] = fields
    return rows


def check_regression(out_lines, baseline_path) -> int:
    """Compare this run's serving rows against the committed baseline.
    Returns the number of regressions (printed); missing rows count IN
    BOTH DIRECTIONS — a baseline row this run no longer produces, and a
    row this run registered that the baseline has never seen (previously
    a new row silently escaped the gate until someone remembered to
    regenerate the baseline)."""
    with open(baseline_path) as f:
        base = parse_rows(f)
    cur = parse_rows(out_lines)
    failures = []
    for name in sorted(set(cur) - set(base)):
        failures.append(
            f"{name}: row not in baseline — regenerate with "
            f"--write-baseline {baseline_path}")
    for name, bfields in sorted(base.items()):
        if name not in cur:
            failures.append(f"{name}: row missing from this run")
            continue
        for metric, (direction, tol) in GATED.items():
            if metric not in bfields:
                continue
            b, c = bfields[metric], cur[name].get(metric)
            if c is None:
                failures.append(f"{name}: metric {metric} disappeared")
                continue
            if direction == "higher":
                bad = c > b * (1 + tol) + 1e-9
            else:
                bad = c < b * (1 - tol) - 1e-9
            if bad:
                failures.append(
                    f"{name}: {metric} {b:g} -> {c:g} "
                    f"({'+' if c > b else ''}{100 * (c - b) / b if b else 0:.0f}%, "
                    f"tol {tol:.0%} {direction}-is-worse)")
    for f_ in failures:
        print(f"REGRESSION {f_}", flush=True)
    if not failures:
        print(f"# regression gate: {len(base)} baseline rows OK "
              f"(vs {baseline_path})")
    return len(failures)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer training steps for the accuracy bench")
    ap.add_argument("--skip-accuracy", action="store_true")
    ap.add_argument("--check", metavar="BASELINE",
                    help="compare serving rows against a committed baseline "
                         "CSV; exit non-zero on >tolerance regressions")
    ap.add_argument("--write-baseline", metavar="PATH",
                    help="write this run's serving rows as the new baseline")
    args = ap.parse_args()

    # the serving sweep's tensor-parallel row (--mesh tp2) needs 2 devices;
    # force them on the host platform BEFORE anything imports jax — this is
    # metric-neutral for every other row (tick/latency/kv columns are
    # deterministic and single-device rows never touch device 1)
    if "jax" not in sys.modules:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_count=2")

    out_lines = []
    t0 = time.time()

    from benchmarks import bench_adaptive_search, bench_kernel_speedup

    print("# === adaptive search ablation (paper §3.1) ===", flush=True)
    bench_adaptive_search.run(out_lines)

    print("# === kernel speedup (paper Table 3) ===", flush=True)
    bench_kernel_speedup.run(out_lines)

    print("# === fused attention template: achieved KV bytes vs ref ===",
          flush=True)
    bench_kernel_speedup.run_attention(out_lines)

    print("# === serving: contiguous vs paged vs chunked vs shared-prefix "
          "vs speculative ===", flush=True)
    from benchmarks import bench_serving
    bench_serving.run(out_lines, quick=args.quick)

    if not args.skip_accuracy:
        print("# === format accuracy sweep (paper Table 2 / Fig.3/5) ===",
              flush=True)
        from benchmarks import bench_formats_accuracy
        bench_formats_accuracy.run(out_lines,
                                   steps=80 if args.quick else 250)

    print("# === roofline summary (§Roofline) ===", flush=True)
    bench_roofline(out_lines)

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_results.txt", "w") as f:
        f.write("\n".join(out_lines) + "\n")
    print(f"# done in {time.time()-t0:.0f}s "
          f"({len(out_lines)} rows -> experiments/bench_results.txt)")

    if args.write_baseline:
        serving = [ln for ln in out_lines
                   if ln.startswith(GATED_PREFIXES)]
        with open(args.write_baseline, "w") as f:
            f.write("# bench regression baseline — serving + kernel_attn "
                    "rows of a --quick sweep.\n# Gated metrics (see "
                    "benchmarks/run.py GATED): ticks, ttft/latency tick\n"
                    "# percentiles, kv_bytes_per_token, kv_vs_floor, "
                    "dequant_kb — deterministic\n# given the seed; 15% "
                    "tolerance (dequant_kb=0 rows pin exactly).\n"
                    "# Regenerate: python -m benchmarks.run --quick "
                    "--write-baseline benchmarks/baseline.csv\n")
            f.write("\n".join(serving) + "\n")
        print(f"# wrote {len(serving)} serving rows -> {args.write_baseline}")

    if args.check:
        n_bad = check_regression(out_lines, args.check)
        if n_bad:
            sys.exit(f"{n_bad} bench regression(s) vs {args.check}")


if __name__ == "__main__":
    main()
