"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (and tees a copy into
experiments/bench_results.txt):

    Table 2 / Fig.3 / Fig.5  -> bench_formats_accuracy (CE + weight-MSE proxy)
    §3.1 Adaptive Searching  -> bench_adaptive_search
    Table 3 / Fig.6          -> bench_kernel_speedup (analytic Table-3 model
                                + CPU wall-clock plumbing check)
    Serving (beyond-paper)   -> bench_serving (fp16 vs AMS engine throughput
                                under one Poisson workload: contiguous,
                                paged, chunked-prefill, and shared-prefix
                                (prefix-cache hit rate / cached-token
                                fraction) rows in the same CSV)
    §Roofline summary        -> bench_roofline (reads experiments/dryrun)

Run: PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def bench_roofline(out_lines):
    """Summarize dry-run roofline terms if dry-run records exist."""
    try:
        from repro.analysis.roofline import analyze, load_records
    except Exception as e:  # pragma: no cover
        print(f"roofline/skip,0,import_error={e!r}")
        return
    recs = load_records("experiments/dryrun", "pod256")
    if not recs:
        line = "roofline/skip,0,no dry-run records (run repro.launch.dryrun)"
        print(line)
        out_lines.append(line)
        return
    for r in recs:
        a = analyze(r)
        line = (f"roofline/{r['arch']}/{r['shape']},0,"
                f"dom={a['dominant']} compute_s={a['compute_s']:.4g} "
                f"memory_s={a['memory_s']:.4g} "
                f"collective_s={a['collective_s']:.4g} "
                f"useful={a['useful_flops_ratio']} "
                f"roofline_frac={a['roofline_fraction']}")
        print(line, flush=True)
        out_lines.append(line)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer training steps for the accuracy bench")
    ap.add_argument("--skip-accuracy", action="store_true")
    args = ap.parse_args()

    out_lines = []
    t0 = time.time()

    from benchmarks import bench_adaptive_search, bench_kernel_speedup

    print("# === adaptive search ablation (paper §3.1) ===", flush=True)
    bench_adaptive_search.run(out_lines)

    print("# === kernel speedup (paper Table 3) ===", flush=True)
    bench_kernel_speedup.run(out_lines)

    print("# === serving: contiguous vs paged vs chunked vs shared-prefix ===",
          flush=True)
    from benchmarks import bench_serving
    bench_serving.run(out_lines, quick=args.quick)

    if not args.skip_accuracy:
        print("# === format accuracy sweep (paper Table 2 / Fig.3/5) ===",
              flush=True)
        from benchmarks import bench_formats_accuracy
        bench_formats_accuracy.run(out_lines,
                                   steps=80 if args.quick else 250)

    print("# === roofline summary (§Roofline) ===", flush=True)
    bench_roofline(out_lines)

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_results.txt", "w") as f:
        f.write("\n".join(out_lines) + "\n")
    print(f"# done in {time.time()-t0:.0f}s "
          f"({len(out_lines)} rows -> experiments/bench_results.txt)")


if __name__ == "__main__":
    main()
