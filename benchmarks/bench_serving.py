"""Continuous-batching serving benchmark: fp16 vs AMS-quantized in one run.

Drives ``repro.launch.engine.ServeEngine`` under a Poisson open-loop arrival
process (the "heavy traffic" shape: requests arrive on their own schedule,
not when the server is ready) and reports, per scheme:

  * tokens/sec           — aggregate decode throughput over the run
  * p50 / p99 per-token  — wall-clock per engine tick that produced tokens
    latency                (every in-flight request advances one token/tick,
                            so tick latency IS per-token latency)
  * mean request latency — submit -> finish, in ticks (queueing included)
  * utilization          — mean fraction of KV slots busy

On CPU the quantized path pays dequantization compute, so the fp16-relative
speedup here validates *plumbing*, not the paper's memory-bound 2.8-3.2x —
that needs accelerator HBM bandwidth (see benchmarks/bench_kernel_speedup.py
for the analytic Table-3 model). Arrivals are tick-indexed (deterministic
given --seed) so both schemes see the IDENTICAL workload.

Run (reduced, CPU):
    PYTHONPATH=src python -m benchmarks.bench_serving --reduced

CSV lines go to stdout in the benchmarks/run.py style:
    serving/<scheme>,<us_per_token>,tokens_per_s=... p50_ms=... p99_ms=...
"""

from __future__ import annotations

import argparse

import numpy as np


def poisson_workload(n_requests: int, rate: float, prompt_mean: int,
                     gen_tokens: int, vocab: int, seed: int):
    """Tick-indexed open-loop workload: (arrival_tick, prompt, max_tokens).

    Inter-arrival gaps are geometric (discrete-time Poisson process at
    `rate` requests/tick); prompt lengths are Poisson around prompt_mean.
    """
    rng = np.random.default_rng(seed)
    gaps = rng.geometric(min(rate, 1.0), n_requests)
    arrivals = np.cumsum(gaps) - gaps[0]  # first request at tick 0
    work = []
    for t in arrivals:
        plen = max(1, int(rng.poisson(prompt_mean)))
        work.append((int(t), rng.integers(0, vocab, plen), gen_tokens))
    return work


def run_scheme(scheme: str, work, args):
    from repro.launch.engine import ServeEngine

    eng = ServeEngine(args.arch, reduced=args.reduced, scheme=scheme,
                      impl=args.impl, slots=args.slots,
                      capacity=args.capacity, seed=args.seed,
                      verbose=not args.quiet)
    # warm the jit before the clock matters: one throwaway request, then
    # drop its ticks from the metrics (compile would otherwise land in p99)
    warm = eng.submit(np.zeros(1, np.int64), 1)
    eng.run()
    assert warm.done
    eng.reset_metrics()

    reqs, pending = [], list(work)
    util = []
    while pending or eng.has_work:
        while pending and pending[0][0] <= eng.tick:
            _, prompt, mt = pending.pop(0)
            reqs.append(eng.submit(prompt, mt))
        eng.step()
        util.append(eng.active_count / args.slots)

    s = eng.stats()
    lat_ticks = [r.finish_tick - r.submit_tick for r in reqs]
    return {
        "tokens_per_s": s["tokens_per_s"],
        "p50_ms": s["decode_ms_median"],
        "p99_ms": s["decode_ms_p99"],
        "req_latency_ticks": float(np.mean(lat_ticks)),
        "utilization": float(np.mean(util)),
        "ticks": s["ticks"],
        "tokens": s["tokens_generated"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True, help="--no-reduced runs the full config")
    ap.add_argument("--schemes", default="fp16,fp5.33-e2m3",
                    help="comma-separated; all run against the same workload")
    ap.add_argument("--impl", default="ref",
                    choices=["ref", "fused_ref", "pallas", "pallas_interpret"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.3,
                    help="mean arrivals per engine tick (Poisson)")
    ap.add_argument("--prompt-mean", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=8, help="per request")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs import get_config
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    work = poisson_workload(args.requests, args.rate, args.prompt_mean,
                            args.tokens, cfg.vocab_size, args.seed)

    results = {}
    for scheme in args.schemes.split(","):
        scheme = scheme.strip()
        results[scheme] = r = run_scheme(scheme, work, args)
        us_per_tok = 1e6 / r["tokens_per_s"] if r["tokens_per_s"] else 0.0
        print(f"serving/{scheme},{us_per_tok:.1f},"
              f"tokens_per_s={r['tokens_per_s']:.2f} "
              f"p50_ms={r['p50_ms']:.2f} p99_ms={r['p99_ms']:.2f} "
              f"req_latency_ticks={r['req_latency_ticks']:.1f} "
              f"util={r['utilization']:.2f}", flush=True)

    if "fp16" in results:
        base = results["fp16"]["tokens_per_s"]
        for scheme, r in results.items():
            if scheme != "fp16" and base:
                print(f"serving/speedup_vs_fp16/{scheme},0,"
                      f"x={r['tokens_per_s'] / base:.2f} "
                      f"(CPU: compute-bound; paper's 2.8-3.2x is HBM-bound)")
    return results


if __name__ == "__main__":
    main()
