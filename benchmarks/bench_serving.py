"""Continuous-batching serving benchmark: fp16 vs AMS-quantized in one run.

Drives ``repro.launch.engine.ServeEngine`` under a Poisson open-loop arrival
process (the "heavy traffic" shape: requests arrive on their own schedule,
not when the server is ready) and reports, per scheme x cache mode:

  * tokens/sec           — aggregate decode throughput over the run
  * p50 / p99 per-token  — wall-clock per engine tick that produced tokens
    latency                (decoding requests advance one token per tick)
  * TTFT p50 / p99       — submit -> FIRST generated token, in ticks; the
                            headline number ragged chunked prefill
                            (``--chunk C``) moves: ceil(prompt/C) prefill
                            ticks instead of one tick per prompt position
  * request latency      — submit -> finish p50/p99 + mean, in ticks
                            (queueing included)
  * utilization          — mean fraction of KV slots busy

On CPU the quantized path pays dequantization compute, so the fp16-relative
speedup here validates *plumbing*, not the paper's memory-bound 2.8-3.2x —
that needs accelerator HBM bandwidth (see benchmarks/bench_kernel_speedup.py
for the analytic Table-3 model). Arrivals are tick-indexed (deterministic
given --seed) so both schemes see the IDENTICAL workload.

``--temperature`` / ``--top-k`` / ``--top-p`` turn on per-request ON-DEVICE
stochastic sampling (see ``repro.launch.sampling``): every request carries
its own ``SamplingParams`` seeded by its workload index, so the sampled
run is deterministic given ``--seed`` and the TTFT/latency percentile
columns report a realistic sampled workload instead of pure greedy.
``--stop-ids N`` additionally gives each request N random EOS-like stop
tokens, so some streams terminate early instead of at the length cap
(variable-length workload; watch the ``gen_tok_mean`` column).

``--speculate K`` turns on SPECULATIVE DECODING through the same ragged
step (see ``repro.launch.speculative`` / docs/speculative.md): a drafter
(``--drafter ngram|self|self-full``) proposes up to K tokens per decoding
slot and one pass of the quantized weights + KV pool verifies them all.
The ``accept_rate`` and ``tokens_per_step`` CSV columns report how many
drafts survive the (distribution-preserving) rejection rule and how many
tokens each emitting engine round produces — tokens_per_step is the
decode-throughput multiplier speculation buys (1.0 when off). Greedy
speculative streams are bit-identical to non-speculative ones, so the
deterministic tick/latency columns remain gateable.

``--paged`` / ``--contiguous`` selects the KV-cache mode (see
`repro.cache`): paged mode stores the cache as block-table-addressed pages
— packed AMS-e2m2 planes for quantized schemes (paged-AMS, ~3.6x smaller
at hd=128), bf16 pages for fp16 — and admits by free-page budget instead
of worst-case slots. ``--shared-prefix N`` prepends the same N-token
system prompt to every request: with prefix caching (paged modes, default
on) the shared pages prefill once and every later request skips them —
the ``prefix_hit_rate`` / ``cached_frac`` CSV columns report the reuse,
and the TTFT columns show the win. All modes land in the same CSV
(registered in ``benchmarks/run.py``), so fp16 vs AMS-paged serving is one
diffable file.

``--mesh tpN`` runs the SAME engine tensor-parallel on a (1, N) serving
mesh (docs/serving.md §Sharded serving): weights N-sharded, paged KV pools
sharded over kv heads and never gathered. Token streams — and so every
deterministic tick/latency column — are bit-identical to tp=1; the one
column that moves is ``kv_bytes_per_token``, which becomes PER-DEVICE and
scales as 1/N (AMS compression and head sharding multiply). On CPU the N
host devices are forced automatically (XLA_FLAGS) when jax isn't imported
yet.

Run (reduced, CPU):
    PYTHONPATH=src python -m benchmarks.bench_serving --reduced --paged

CSV lines go to stdout in the benchmarks/run.py style:
    serving/<scheme>/<cache-mode>,<us_per_token>,tokens_per_s=... p50_ms=...
"""

from __future__ import annotations

import argparse
import os

import numpy as np


def poisson_workload(n_requests: int, rate: float, prompt_mean: int,
                     gen_tokens: int, vocab: int, seed: int,
                     shared_prefix: int = 0):
    """Tick-indexed open-loop workload: (arrival_tick, prompt, max_tokens).

    Inter-arrival gaps are geometric (discrete-time Poisson process at
    `rate` requests/tick); prompt lengths are Poisson around prompt_mean.
    With ``shared_prefix=N`` every prompt starts with the same N-token
    system prompt — the prefix-cache workload: in paged modes each full
    shared page prefills (and quantizes) once, every later request
    references it.
    """
    rng = np.random.default_rng(seed)
    gaps = rng.geometric(min(rate, 1.0), n_requests)
    arrivals = np.cumsum(gaps) - gaps[0]  # first request at tick 0
    prefix = rng.integers(0, vocab, shared_prefix) if shared_prefix else None
    work = []
    for t in arrivals:
        plen = max(1, int(rng.poisson(prompt_mean)))
        prompt = rng.integers(0, vocab, plen)
        if prefix is not None:
            prompt = np.concatenate([prefix, prompt])
        work.append((int(t), prompt, gen_tokens))
    return work


def cache_config_for(scheme: str, args):
    """--paged maps to paged-AMS for quantized schemes, paged-bf16 for fp16.
    --impl carries over to the paged-attention path too (fused_ref has no
    cache analogue — the gather-dequantize ref IS the XLA fallback)."""
    if args.cache_mode != "paged":
        return None
    from repro.cache import CacheConfig
    kind = "paged_bf16" if scheme == "fp16" else "paged_ams"
    cache_impl = args.impl if args.impl in ("pallas", "pallas_interpret") else "ref"
    return CacheConfig(kind=kind, page_size=args.page_size, impl=cache_impl)


def sampling_for(args, i: int, vocab: int):
    """Per-request SamplingParams for workload item i (None = greedy).
    Seeded by the workload index, so the sampled run replays
    bit-identically across schemes and engine instances."""
    if args.temperature <= 0 and not args.stop_ids:
        return None
    from repro.launch.sampling import SamplingParams
    stop = ()
    if args.stop_ids:
        stop = tuple(np.random.default_rng(args.seed + i)
                     .integers(0, vocab, args.stop_ids).tolist())
    return SamplingParams(temperature=args.temperature, top_k=args.top_k,
                          top_p=args.top_p, seed=args.seed + i,
                          stop_token_ids=stop)


def mesh_for(args):
    """--mesh tpN -> a (1, N) serving mesh (None when off). Needs N visible
    devices; `main` forces them via XLA_FLAGS when jax isn't imported yet,
    so by the time this runs a shortfall is a real environment problem."""
    if not args.mesh:
        return None
    import jax

    from repro.launch.mesh import make_serving_mesh
    tp = int(args.mesh[2:])
    if len(jax.devices()) < tp:
        raise SystemExit(
            f"--mesh {args.mesh} needs {tp} devices but jax sees "
            f"{len(jax.devices())}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={tp} before jax loads")
    return make_serving_mesh(tp)


def engine_config_for(scheme: str, args, obs=None):
    """One frozen EngineConfig per (scheme, CLI) combination — the bench
    drives the same redesigned constructor surface users get
    (repro.serving), not the deprecated kwargs shim."""
    from repro.serving import EngineConfig
    kw = {}
    if obs is not None:
        kw["obs"] = obs
    return EngineConfig(arch=args.arch, reduced=args.reduced, scheme=scheme,
                        impl=args.impl, slots=args.slots,
                        capacity=args.capacity, seed=args.seed,
                        cache=cache_config_for(scheme, args),
                        prefill_chunk=args.chunk,
                        speculate_k=args.speculate, drafter=args.drafter,
                        mesh=mesh_for(args), verbose=not args.quiet, **kw)


def _drive(scheme: str, work, args, vocab: int, obs=None):
    """Build a ServeEngine, warm the jit, drive the full workload.
    Returns (engine, request handles, per-tick utilization)."""
    from repro.serving import ServeEngine

    eng = ServeEngine(engine_config_for(scheme, args, obs=obs))
    # warm the jit before the clock matters: one throwaway request, then
    # drop its ticks from the metrics (compile would otherwise land in p99)
    warm = eng.submit(np.zeros(1, np.int32), 1)
    eng.run()
    assert warm.done
    eng.reset_metrics()

    reqs, pending = [], [(i, *w) for i, w in enumerate(work)]
    util = []
    while pending or eng.has_work:
        while pending and pending[0][1] <= eng.tick:
            i, _, prompt, mt = pending.pop(0)
            reqs.append(eng.submit(prompt, mt,
                                   sampling=sampling_for(args, i, vocab)))
        eng.step()
        util.append(eng.active_count / args.slots)
    return eng, reqs, util


def obs_check(eng, reqs, scheme: str, work, args, vocab: int, out_lines):
    """The telemetry zero-perturbation assertion: replay the IDENTICAL
    workload with observability disabled (no registry, no spans, no cost
    model) and require every deterministic output to match bit-for-bit —
    engine ticks to drain, every token stream, every lifecycle tick.
    Telemetry that moved any of these would silently invalidate the
    committed bench baseline; this turns that into a loud failure."""
    from repro.obs import ObsConfig

    eng2, reqs2, _ = _drive(scheme, work, args, vocab,
                            obs=ObsConfig(enabled=False))
    assert eng.tick == eng2.tick, (
        f"obs-check: tick count moved with telemetry on "
        f"({eng.tick} vs {eng2.tick})")
    assert len(reqs) == len(reqs2)
    for a, b in zip(reqs, reqs2):
        assert a.tokens_so_far() == b.tokens_so_far(), (
            f"obs-check: request {a.rid} token stream diverged")
        assert (a.first_token_tick, a.finish_tick, a.finish_reason) == (
            b.first_token_tick, b.finish_tick, b.finish_reason), (
            f"obs-check: request {a.rid} lifecycle diverged")
    assert eng.kv_bytes_per_token() == eng2.kv_bytes_per_token()
    line = (f"# obs-check/{scheme}: telemetry perturbation 0% "
            f"(ticks={eng.tick} streams={len(reqs)} identical with obs off)")
    print(line, flush=True)
    out_lines.append(line)


def run_scheme(scheme: str, work, args, vocab: int, out_lines=None):
    obs = None
    if args.trace:
        from repro.obs import ObsConfig
        obs = ObsConfig(trace=True)
    eng, reqs, util = _drive(scheme, work, args, vocab, obs=obs)
    s = eng.stats()

    if args.trace:
        # per-scheme artifact pair: Perfetto/chrome trace + Prometheus
        # snapshot (load the .json at ui.perfetto.dev, scrape the .prom)
        base, ext = os.path.splitext(args.trace)
        if os.path.dirname(base):
            os.makedirs(os.path.dirname(base), exist_ok=True)
        trace_path = f"{base}-{scheme}{ext or '.json'}"
        prom_path = f"{base}-{scheme}.prom"
        eng.trace.save(trace_path)
        eng.metrics.write_prom(prom_path)
        print(f"# trace/{scheme}: wrote {trace_path} + {prom_path}",
              flush=True)
    if args.hlo_cost:
        from repro.obs import attribution
        rep = attribution(eng, hlo=True)
        print(f"# hlo-cost/{scheme}: "
              f"hlo_flops_per_tick={rep.get('hlo_flops_per_tick', 0):.4g} "
              f"hlo_hbm_bytes_per_tick="
              f"{rep.get('hlo_hbm_bytes_per_tick', 0):.4g} "
              f"floor_hbm_bytes_per_tick="
              f"{rep.get('floor_hbm_bytes_per_tick', 0):.4g}", flush=True)
    if args.obs_check:
        obs_check(eng, reqs, scheme, work, args, vocab,
                  out_lines if out_lines is not None else [])
    # eng.finished after the warmup reset == reqs, so stats() IS the
    # per-request latency source (no second hand-rolled computation)
    return {
        "tokens_per_s": s["tokens_per_s"],
        "p50_ms": s["decode_ms_median"],
        "p99_ms": s["decode_ms_p99"],
        "req_latency_ticks": s["latency_ticks_mean"],
        "ttft_ticks_p50": s["ttft_ticks_p50"],
        "ttft_ticks_p99": s["ttft_ticks_p99"],
        "latency_ticks_p50": s["latency_ticks_p50"],
        "latency_ticks_p99": s["latency_ticks_p99"],
        "utilization": float(np.mean(util)),
        "ticks": s["ticks"],
        "tokens": s["tokens_generated"],
        # variable-length workloads (sampling + stop tokens): mean actual
        # generated length and how many requests stopped before the cap
        "gen_tok_mean": s["gen_tokens_mean"],
        "stopped_early": s["stopped_early"],
        "kv_bytes_per_token": s["kv_bytes_per_token"],
        "kv_compression": s["kv_compression_vs_bf16"],
        # prefix-cache effectiveness (0.0 in contiguous mode / cache off)
        "prefix_hit_rate": s.get("prefix_hit_rate", 0.0),
        "cached_frac": s.get("cached_token_frac", 0.0),
        # speculative decoding (accept_rate 0.0 / tokens_per_step 1.0 when
        # --speculate is off): tokens emitted per emitting engine round is
        # the decode-throughput multiplier speculation buys
        "accept_rate": s["accept_rate"],
        "tokens_per_step": s["tokens_per_step"],
    }


def run_overload(out_lines, quick: bool = False, seed: int = 0):
    """Poisson-OVERLOAD row: a two-class workload against a slot-saturated
    engine, preemptive priority scheduling vs head-of-line blocking.

    Batch requests (priority 0, long generations) saturate every slot from
    tick 0; short interactive requests (priority 5) arrive Poisson on top.
    Both policies see the IDENTICAL workload and page budget — the HOL
    baseline submits the same interactive requests at priority 0, so they
    wait for a batch slot to drain. The headline is the interactive class's
    p99 TTFT: under preemption a blocked interactive head spills the
    youngest batch request to the host tier (packed AMS planes, restored
    bit-exactly on resume) and runs now. The row hard-asserts preemptive
    p99 TTFT strictly beats HOL, and the gated tick/ttft/preemption
    columns pin the scheduling behaviour (deterministic given the seed).
    """
    from repro.serving import CacheConfig, EngineConfig, SamplingParams, \
        ServeEngine
    from repro.configs import get_config

    scheme = "fp5.33-e2m3"
    cfg = get_config("qwen2-7b").reduced()
    vocab = cfg.vocab_size
    rng = np.random.default_rng(seed)
    n_batch, n_inter = (2, 3) if quick else (3, 5)
    batch = [(0, rng.integers(0, vocab, 10), 24) for _ in range(n_batch)]
    gaps = rng.geometric(0.12, n_inter)
    inter = [(int(t), rng.integers(0, vocab, 4), 4)
             for t in (np.cumsum(gaps) + 2)]

    def drive(interactive_priority):
        ec = EngineConfig(scheme=scheme, slots=2, capacity=48, seed=seed,
                          cache=CacheConfig(kind="paged_ams", page_size=8,
                                            host_spill_pages=64),
                          verbose=False)
        eng = ServeEngine(ec)
        warm = eng.submit(np.zeros(1, np.int32), 1)
        eng.run()
        assert warm.done
        eng.reset_metrics()
        work = ([(t, 0, p, mt, 0) for t, p, mt in batch]
                + [(t, 1, p, mt, interactive_priority) for t, p, mt in inter])
        handles = []
        pending = sorted(enumerate(work), key=lambda kv: (kv[1][0], kv[0]))
        pending = [w for _, w in pending]
        while pending or eng.has_work:
            while pending and pending[0][0] <= eng.tick:
                t, is_inter, prompt, mt, prio = pending.pop(0)
                h = eng.submit(prompt, mt, priority=prio,
                               sampling=SamplingParams(seed=seed))
                handles.append((t, is_inter, h))
            eng.step()
        return eng, handles

    eng_p, hs_p = drive(interactive_priority=5)
    eng_h, hs_h = drive(interactive_priority=0)     # head-of-line baseline

    # submit tick == arrival tick here, so TTFT is queueing-inclusive
    t_p = np.asarray([h.first_token_tick - t
                      for t, i, h in hs_p if i], np.float64)
    t_h = np.asarray([h.first_token_tick - t
                      for t, i, h in hs_h if i], np.float64)
    # identical token streams: priority moves WHEN, never WHAT
    for (_, _, a), (_, _, b) in zip(hs_p, hs_h):
        assert a.tokens_so_far() == b.tokens_so_far(), (
            f"overload: request {a.rid} stream diverged between policies")
    p99_p, p99_h = np.percentile(t_p, 99), np.percentile(t_h, 99)
    assert p99_p < p99_h, (
        f"preemptive p99 TTFT ({p99_p:.1f} ticks) must strictly beat "
        f"head-of-line blocking ({p99_h:.1f} ticks) on the same page budget")
    s = eng_p.stats()
    assert s["preemptions"] >= 1 and s["resumes"] >= 1, s["preemptions"]
    line = (f"serving/overload/{scheme}/preempt,0,"
            f"ticks={eng_p.tick} "
            f"ttft_ticks_p50={np.percentile(t_p, 50):.1f} "
            f"ttft_ticks_p99={p99_p:.1f} "
            f"hol_ttft_ticks_p99={p99_h:.1f} "
            f"preemptions={s['preemptions']} resumes={s['resumes']} "
            f"spill_pages={s['spill_pages']} "
            f"host_spill_pages={s.get('host_spill_pages_total', 0)} "
            f"kv_bytes_per_token={s['kv_bytes_per_token']}")
    print(line, flush=True)
    out_lines.append(line)


def main(argv=None, out_lines=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True, help="--no-reduced runs the full config")
    ap.add_argument("--schemes", default="fp16,fp5.33-e2m3",
                    help="comma-separated; all run against the same workload")
    ap.add_argument("--impl", default="ref",
                    choices=["ref", "fused_ref", "pallas", "pallas_interpret"])
    ap.add_argument("--paged", dest="cache_mode", action="store_const",
                    const="paged", default="contiguous",
                    help="paged KV cache (AMS-packed pages for quantized "
                         "schemes, bf16 pages for fp16)")
    ap.add_argument("--contiguous", dest="cache_mode", action="store_const",
                    const="contiguous",
                    help="fixed [slots, capacity] bf16 KV cache (default)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged modes)")
    ap.add_argument("--chunk", type=int, default=1,
                    help="ragged prefill chunk size C: prefilling slots "
                         "consume up to C prompt tokens per tick (1 = the "
                         "one-token-per-tick step)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend the same N-token system prompt to every "
                         "request — the prefix-cache workload (paged modes "
                         "share the N-token pages; watch prefix_hit_rate "
                         "and ttft)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy); "
                         "sampled runs are seeded per request index")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--stop-ids", type=int, default=0,
                    help="give each request N random stop tokens "
                         "(EOS-like early termination; max 8)")
    ap.add_argument("--speculate", type=int, default=0,
                    help="speculative decoding: score up to K draft tokens "
                         "per decoding slot in the same ragged step "
                         "(0 = off); adds accept_rate / tokens_per_step "
                         "CSV columns")
    ap.add_argument("--drafter", default="ngram",
                    choices=["ngram", "self", "self-full"],
                    help="draft proposer: n-gram prompt lookup (free), "
                         "truncated-stack self-draft, or full-stack "
                         "self-draft (the accept-rate ceiling)")
    ap.add_argument("--trace", metavar="PATH", default="",
                    help="dump a Perfetto-loadable chrome trace + Prometheus "
                         "snapshot per scheme: PATH-<scheme>.json / .prom "
                         "(enables per-request spans + synchronous device-"
                         "step timing; wall-clock columns only, the "
                         "deterministic tick/kv columns are unchanged)")
    ap.add_argument("--obs-check", action="store_true",
                    help="re-run each scheme's workload with observability "
                         "disabled and assert 0%% perturbation: identical "
                         "ticks, token streams and lifecycle ticks")
    ap.add_argument("--hlo-cost", action="store_true",
                    help="lower+compile the engine step and print XLA's own "
                         "per-tick FLOP/HBM-byte estimate next to the "
                         "analytic roofline floor")
    ap.add_argument("--mesh", default="",
                    help="'tpN': run the engine tensor-parallel on a "
                         "(1, N) serving mesh — weights N-sharded, paged "
                         "KV pools head-sharded (never gathered), token "
                         "streams bit-identical to tp=1; the CSV row gains "
                         "a /tpN tag and kv_bytes_per_token becomes "
                         "PER-DEVICE (scales 1/N). On CPU the N host "
                         "devices are forced automatically when jax isn't "
                         "loaded yet")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.3,
                    help="mean arrivals per engine tick (Poisson)")
    ap.add_argument("--prompt-mean", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=8, help="per request")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.mesh:
        import sys
        if not (args.mesh.startswith("tp") and args.mesh[2:].isdigit()):
            ap.error(f"--mesh wants 'tpN', got {args.mesh!r}")
        # force the host-platform device count while it can still take
        # effect (before the first jax import — the module top imports only
        # argparse/os/numpy for exactly this reason); inside benchmarks/run
        # the driver has already forced devices and jax may be live
        if "jax" not in sys.modules:
            os.environ["XLA_FLAGS"] = (
                os.environ.get("XLA_FLAGS", "")
                + f" --xla_force_host_platform_device_count={args.mesh[2:]}")

    out_lines = out_lines if out_lines is not None else []

    from repro.configs import get_config
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    work = poisson_workload(args.requests, args.rate, args.prompt_mean,
                            args.tokens, cfg.vocab_size, args.seed,
                            shared_prefix=args.shared_prefix)

    mode = args.cache_mode
    if args.chunk > 1:
        mode = f"{mode}/chunk{args.chunk}"
    if args.shared_prefix:
        mode = f"{mode}/shared{args.shared_prefix}"
    if args.temperature > 0:
        mode = f"{mode}/sampled-t{args.temperature:g}-p{args.top_p:g}"
    if args.stop_ids:
        mode = f"{mode}/stop{args.stop_ids}"
    if args.speculate:
        mode = f"{mode}/spec{args.speculate}-{args.drafter}"
    if args.mesh:
        mode = f"{mode}/{args.mesh}"
    results = {}
    for scheme in args.schemes.split(","):
        scheme = scheme.strip()
        results[scheme] = r = run_scheme(scheme, work, args, cfg.vocab_size,
                                         out_lines=out_lines)
        us_per_tok = 1e6 / r["tokens_per_s"] if r["tokens_per_s"] else 0.0
        line = (f"serving/{scheme}/{mode},{us_per_tok:.1f},"
                f"tokens_per_s={r['tokens_per_s']:.2f} "
                f"ticks={r['ticks']} "
                f"p50_ms={r['p50_ms']:.2f} p99_ms={r['p99_ms']:.2f} "
                f"req_latency_ticks={r['req_latency_ticks']:.1f} "
                f"ttft_ticks_p50={r['ttft_ticks_p50']:.1f} "
                f"ttft_ticks_p99={r['ttft_ticks_p99']:.1f} "
                f"latency_ticks_p50={r['latency_ticks_p50']:.1f} "
                f"latency_ticks_p99={r['latency_ticks_p99']:.1f} "
                f"util={r['utilization']:.2f} "
                f"gen_tok_mean={r['gen_tok_mean']:.2f} "
                f"stopped_early={r['stopped_early']} "
                f"kv_bytes_per_token={r['kv_bytes_per_token']} "
                f"kv_compression={r['kv_compression']:.2f} "
                f"prefix_hit_rate={r['prefix_hit_rate']:.2f} "
                f"cached_frac={r['cached_frac']:.2f} "
                f"accept_rate={r['accept_rate']:.2f} "
                f"tokens_per_step={r['tokens_per_step']:.2f}")
        print(line, flush=True)
        out_lines.append(line)

    if "fp16" in results:
        base = results["fp16"]["tokens_per_s"]
        for scheme, r in results.items():
            if scheme != "fp16" and base:
                line = (f"serving/speedup_vs_fp16/{scheme}/{mode},0,"
                        f"x={r['tokens_per_s'] / base:.2f} "
                        f"(CPU: compute-bound; paper's 2.8-3.2x is HBM-bound)")
                print(line, flush=True)
                out_lines.append(line)
    return results


def run(out_lines, quick: bool = False):
    """benchmarks/run.py entry: fp16 vs AMS under the SAME Poisson workload,
    contiguous AND paged cache modes, a ragged chunked-prefill run (chunk=4
    — the TTFT columns are what that row moves), a shared-prefix run
    (all requests share a 16-token system prompt — prefix_hit_rate /
    cached_frac / ttft are what prefix caching moves), a SAMPLED run
    (per-request temperature-0.8/top-p-0.9 with stop tokens — the
    TTFT/latency percentiles under a realistic stochastic, variable-length
    workload), and a SPECULATIVE run (k=4 full-stack self-drafting on the
    shared-prefix workload — the accept_rate / tokens_per_step columns are
    what speculation moves, with the greedy streams still bit-identical
    so the tick metrics stay gated), all in one CSV.

    Telemetry satellites (repro.obs) ride the sweep: the paged row re-runs
    with observability disabled and asserts 0% perturbation (--obs-check),
    and the shared-prefix + speculative row dumps a Perfetto trace +
    Prometheus snapshot per scheme into experiments/ (--trace) — the CI
    bench job uploads them as artifacts.

    A TENSOR-PARALLEL row (--mesh tp2, needs benchmarks/run.py's forced
    2-device host platform) re-runs the paged chunked workload sharded and
    asserts the sharded-serving contract right in the sweep: every
    deterministic metric byte-identical to the tp=1 row, and the
    PER-DEVICE kv_bytes_per_token exactly halved."""
    argv = ["--quiet", "--requests", "3" if quick else "6",
            "--tokens", "4", "--slots", "2", "--capacity", "32",
            "--rate", "0.5", "--prompt-mean", "6", "--page-size", "8"]
    sweep_results = {}
    for extra in (["--contiguous"], ["--paged", "--obs-check"],
                  ["--paged", "--chunk", "4"],
                  ["--paged", "--chunk", "4", "--shared-prefix", "16",
                   "--capacity", "48"],
                  ["--paged", "--temperature", "0.8", "--top-p", "0.9",
                   "--stop-ids", "4"],
                  # the spec row needs generation headroom (k=4 drafts per
                  # round only pay off past a few emitted rounds)
                  ["--paged", "--chunk", "4", "--shared-prefix", "16",
                   "--capacity", "48", "--tokens", "12",
                   "--speculate", "4", "--drafter", "self-full",
                   "--trace", "experiments/serving_trace.json"],
                  ["--paged", "--chunk", "4", "--mesh", "tp2"]):
        sweep_results[tuple(extra)] = main(argv + extra, out_lines=out_lines)

    # Poisson-overload row: preemptive priority scheduling + host-tier KV
    # spill vs head-of-line blocking — asserts the interactive class's p99
    # TTFT strictly improves, gates ticks/ttft/preemption counts
    run_overload(out_lines, quick=quick)

    # sharded-serving gate: tp2 vs the matching tp1 paged/chunk4 row
    tp1 = sweep_results[("--paged", "--chunk", "4")]
    tp2 = sweep_results[("--paged", "--chunk", "4", "--mesh", "tp2")]
    deterministic = ("ticks", "tokens", "ttft_ticks_p50", "ttft_ticks_p99",
                     "latency_ticks_p50", "latency_ticks_p99",
                     "req_latency_ticks", "utilization", "gen_tok_mean",
                     "stopped_early", "prefix_hit_rate", "cached_frac",
                     "accept_rate", "tokens_per_step", "kv_compression")
    for scheme, r2 in tp2.items():
        r1 = tp1[scheme]
        for m in deterministic:
            assert r2[m] == r1[m], (
                f"tp2 row diverged from tp1 on {scheme}/{m}: "
                f"{r2[m]} vs {r1[m]} — sharded serving must be "
                f"bit-identical to single-device")
        assert r2["kv_bytes_per_token"] * 2 == r1["kv_bytes_per_token"], (
            f"per-device kv_bytes_per_token must scale 1/tp: "
            f"{scheme}: {r2['kv_bytes_per_token']} * 2 != "
            f"{r1['kv_bytes_per_token']}")


if __name__ == "__main__":
    main()
