"""Paper Table 2 / Fig. 3 / Fig. 5 proxy: accuracy vs. quantization scheme.

No pretrained checkpoints exist offline, so the paper's benchmark-accuracy
claim is reproduced as: train a small LM on the synthetic corpus, then
measure held-out cross-entropy with PTQ'd weights under every scheme the
paper evaluates. The paper's claim maps to:

    CE(fp16) ~= CE(fp6-e2m3) ~= CE(fp5.33) < CE(fp5) <= CE(fp4.5)
      <= CE(fp4.33) <= CE(fp4.25) << CE(fp4-e2m1)

plus weight-MSE per scheme (the quantity adaptive search optimizes).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import SCHEMES
from repro.core.policy import QuantPolicy
from repro.data import DataConfig, SyntheticLM
from repro.models import forward_seq
from repro.models.common import quantize_params

EVAL_SCHEMES = [
    ("fp16", None),
    ("fp8", "set_lsb"),
    ("fp6-e2m3", "set_lsb"),
    ("fp6-e3m2", "set_lsb"),
    ("fp5.33-e2m3", "set_lsb"),
    ("fp5.33-e2m3+rq", "requantize"),
    ("fp5-e2m2", "set_lsb"),
    ("fp4.5-e2m2", "set_lsb"),
    ("fp4.33-e2m2", "set_lsb"),
    ("fp4.25-e2m2", "set_lsb"),
    ("fp4.25-e2m2+rq", "requantize"),
    ("fp4-e2m1", "set_lsb"),
]


def train_small_model(steps: int = 250, seed: int = 0):
    """Train a tiny qwen2-family model on synthetic data; return params+cfg."""
    from repro.launch.train import main as train_main
    import tempfile, os

    ckpt = tempfile.mkdtemp(prefix="bench_fmt_")
    train_main([
        "--arch", "qwen2-7b", "--reduced", "--steps", str(steps),
        "--seq-len", "128", "--global-batch", "8", "--lr", "2e-3",
        "--ckpt-dir", ckpt, "--ckpt-every", str(steps), "--log-every", "50",
    ])
    # reload
    from repro.checkpoint import CheckpointManager
    from repro.models import init_params
    from repro.optim import init_state
    cfg = get_config("qwen2-7b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(ckpt)
    restored, _ = mgr.restore({"params": params,
                               "opt": init_state(params)})
    return jax.tree.map(jnp.asarray, restored["params"]), cfg


def eval_ce(params, cfg, policy, n_batches: int = 4, seed: int = 777):
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                                  global_batch=8, seed=seed))
    tot, cnt = 0.0, 0

    @jax.jit
    def ce(p, toks, tgts):
        logits, _, _ = forward_seq(p, toks, cfg, policy=policy, remat=False,
                                   dtype=jnp.float32)
        ls = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(ls, tgts[..., None], axis=-1).mean()

    for b in range(n_batches):
        toks, tgts = data.batch(10_000 + b)
        tot += float(ce(params, jnp.asarray(toks), jnp.asarray(tgts)))
        cnt += 1
    return tot / cnt


def weight_mse(params, policy):
    from repro.core import get_scheme, ams_quantize_dequantize
    s = get_scheme(policy.scheme)
    tot, n = 0.0, 0
    w = params["layers"]["sub0"]["ffn"]["w_up"]["w"]
    for l in range(w.shape[0]):
        wl = w[l][: (w.shape[1] // s.k) * s.k]  # sharing needs K % k == 0
        wq = ams_quantize_dequantize(wl, s, policy.strategy)
        tot += float(jnp.sum((wq - wl) ** 2))
        n += wl.size
    return tot / n


def run(out_lines=None, steps: int = 250):
    params, cfg = train_small_model(steps)
    base = None
    rows = []
    for label, strategy in EVAL_SCHEMES:
        scheme = label.replace("+rq", "")
        t0 = time.time()
        if scheme == "fp16":
            policy, qp = None, None
            ce = eval_ce(params, cfg, None)
            mse = 0.0
        else:
            qp = QuantPolicy(scheme=scheme, strategy=strategy, impl="ref",
                             min_elements=1 << 10)
            qparams = quantize_params(params, qp)
            ce = eval_ce(qparams, cfg, qp)
            mse = weight_mse(params, qp)
        dt = time.time() - t0
        if base is None:
            base = ce
        bits = SCHEMES[scheme].effective_bits if scheme != "fp16" else 16.0
        rows.append((label, bits, ce, ce - base, mse, dt))
        line = (f"formats_accuracy/{label},{1e6*dt:.0f},"
                f"bits={bits:.3f} ce={ce:.4f} delta={ce-base:+.4f} mse={mse:.3e}")
        print(line, flush=True)
        if out_lines is not None:
            out_lines.append(line)
    return rows


if __name__ == "__main__":
    run()
