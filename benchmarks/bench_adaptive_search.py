"""Paper §3.1 ablation: Adaptive Searching vs naive shared-LSB choices.

For each AMS scheme (k in {2,3,4}) and weight distribution, compare the
normalized weight MSE of:
    lsb=0 forced | lsb=1 forced | RTN-majority | adaptive (paper, set_lsb)
    | adaptive-requantize (ours)
The paper's claim: adaptive <= any fixed choice; our requantize refinement
is a further strict improvement.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import SCHEMES, ams_quantize_dequantize, dequantize, quantize_rtn
from repro.core.formats import code_to_value

AMS = ["fp5.33-e2m3", "fp4.5-e2m2", "fp4.33-e2m2", "fp4.25-e2m2"]


def forced_lsb_mse(w, scheme, bit):
    codes, scale = quantize_rtn(w, scheme.base)
    fc = (codes & ~jnp.int32(1)) | bit
    wq = dequantize(fc, scheme.base, scale)
    return float(jnp.mean((wq - w) ** 2))


def majority_mse(w, scheme):
    """Group majority vote of RTN LSBs (a plausible cheap heuristic)."""
    k = scheme.k
    codes, scale = quantize_rtn(w, scheme.base)
    K, N = codes.shape
    Kp = (K // k) * k
    codes = codes[:Kp]
    bits = (codes & 1).reshape(Kp // k, k, N)
    maj = (bits.sum(axis=1) * 2 >= k).astype(jnp.int32)
    maj_full = jnp.repeat(maj, k, axis=0)
    fc = (codes & ~jnp.int32(1)) | maj_full
    wq = dequantize(fc, scheme.base, scale)
    return float(jnp.mean((wq - w[:Kp]) ** 2))


def dists(seed=0):
    rng = np.random.default_rng(seed)
    K, N = 1536, 256
    return {
        "gaussian": rng.standard_normal((K, N)).astype(np.float32) * 0.02,
        "laplace": rng.laplace(size=(K, N)).astype(np.float32) * 0.02,
        "outlier": (rng.standard_normal((K, N)) *
                    (1 + 10 * (rng.random((K, N)) < 0.01))).astype(np.float32) * 0.02,
    }


def run(out_lines=None):
    rows = []
    for dname, w_np in dists().items():
        w = jnp.asarray(w_np)
        for name in AMS:
            s = SCHEMES[name]
            K = (w.shape[0] // s.k) * s.k
            wk = w[:K]
            t0 = time.time()
            m0 = forced_lsb_mse(wk, s, 0)
            m1 = forced_lsb_mse(wk, s, 1)
            mm = majority_mse(wk, s)
            ma = float(jnp.mean((ams_quantize_dequantize(wk, s, "set_lsb") - wk) ** 2))
            mr = float(jnp.mean((ams_quantize_dequantize(wk, s, "requantize") - wk) ** 2))
            dt = time.time() - t0
            assert ma <= min(m0, m1) + 1e-12, (name, dname)
            assert mr <= ma + 1e-12
            line = (f"adaptive_search/{dname}/{name},{1e6*dt:.0f},"
                    f"lsb0={m0:.3e} lsb1={m1:.3e} majority={mm:.3e} "
                    f"adaptive={ma:.3e} requantize={mr:.3e} "
                    f"gain_vs_best_fixed={min(m0,m1)/ma:.3f}x "
                    f"rq_extra={ma/mr:.3f}x")
            print(line, flush=True)
            if out_lines is not None:
                out_lines.append(line)
            rows.append((dname, name, m0, m1, mm, ma, mr))
    return rows


if __name__ == "__main__":
    run()
