"""Paper Table 3: GEMV/linear-layer speedup vs FP16 across batch sizes.

The paper benchmarks CUDA kernels on a ~22 TFLOPS / 290 GB/s GPU. Offline we
reproduce the table two ways:

 1. ANALYTIC (primary, comparable to Table 3): a two-term roofline latency
    model  t = max(bytes/BW, flops/peak) + dequant_overhead  on the paper's
    own GPU constants, per scheme x batch. Packed byte counts come from our
    real PackLayouts (incl. the fp5.33 fused container), dequant overhead
    from the per-weight restore op count of our kernel, amortized at the
    paper's SIMT throughput. Reported as speedup vs fp16, same normalization
    as Table 3.
 2. MEASURED (secondary): CPU wall-clock of the jit'd K-blocked fused path
    vs an fp16 matmul at the same shapes. CPU is compute-bound, so this
    validates functional plumbing, not the memory-bound win (noted).

Paper reference points (Qwen2.5-7B (3584, 18944), batch 1):
    fp8 1.90x | fp6 2.41x | fp5.33 2.68x | fp5 2.81x | fp4.25 3.05x
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SCHEMES, get_scheme, quantize_linear
from repro.core.packing import make_layout
from repro.kernels import ref

# the paper's efficiency rig (§4.2): ~22 TFLOPS fp16, 290 GB/s
GPU_PEAK = 22e12
GPU_BW = 290e9
# per-restored-weight bit-op cost (SHIFT/AND/OR/select ~ 8 ops), at ~1/4 of
# peak scalar throughput — matches TC-FPx's reported dequant overhead scale
DEQ_OPS_PER_WEIGHT = 8.0
DEQ_THROUGHPUT = GPU_PEAK / 4

SHAPES = {
    "qwen3-4b": (2560, 9728),
    "qwen2.5-7b": (3584, 18944),
    "qwen3-32b": (5120, 25600),
}
BATCHES = [1, 2, 4, 8, 16, 32]
EVAL = ["fp16", "fp8", "fp6-e2m3", "fp5.33-e2m3", "fp5-e2m2", "fp4.25-e2m2"]


def analytic_latency(scheme_name: str, K: int, N: int, B: int) -> float:
    flops = 2.0 * B * K * N
    act_bytes = 2.0 * B * (K + N)
    if scheme_name == "fp16":
        w_bytes = 2.0 * K * N
        deq = 0.0
    else:
        lay = make_layout(SCHEMES[scheme_name])
        w_bytes = lay.packed_bytes(K, N) + 4.0 * N  # planes + f32 scales
        deq = DEQ_OPS_PER_WEIGHT * K * N / DEQ_THROUGHPUT
    t_mem = (w_bytes + act_bytes) / GPU_BW
    t_cmp = flops / GPU_PEAK + deq
    return max(t_mem, t_cmp)


def run(out_lines=None, measure: bool = True):
    rows = []
    for model, (K, N) in SHAPES.items():
        base = {b: analytic_latency("fp16", K, N, b) for b in BATCHES}
        for s in EVAL:
            sp = [base[b] / analytic_latency(s, K, N, b) for b in BATCHES]
            line = (f"kernel_speedup/{model}/{s},0," +
                    " ".join(f"b{b}={v:.2f}x" for b, v in zip(BATCHES, sp)))
            print(line, flush=True)
            if out_lines is not None:
                out_lines.append(line)
            rows.append((model, s, sp))

    if measure:
        # CPU wall-clock sanity at a reduced shape (compute-bound on CPU)
        K2, N2, B2 = 1024, 2048, 4
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.standard_normal((K2, N2)).astype(np.float32) * 0.02)
        x = jnp.asarray(rng.standard_normal((B2, K2)).astype(np.float32))

        f16 = jax.jit(lambda x, w: x @ w)
        _ = f16(x, w).block_until_ready()
        t0 = time.time()
        for _ in range(10):
            f16(x, w).block_until_ready()
        t_fp16 = (time.time() - t0) / 10

        for s in ("fp5.33-e2m3", "fp4.25-e2m2"):
            q = quantize_linear(w, get_scheme(s))
            fq = jax.jit(lambda x, pw=q.packed: ref.ams_matmul_blocked(x, pw))
            _ = fq(x).block_until_ready()
            t0 = time.time()
            for _ in range(10):
                fq(x).block_until_ready()
            t_q = (time.time() - t0) / 10
            line = (f"kernel_cpu_wallclock/{s},{1e6*t_q:.0f},"
                    f"fp16_us={1e6*t_fp16:.0f} ratio={t_fp16/t_q:.2f}x "
                    f"(CPU compute-bound; memory-bound win needs TPU BW)")
            print(line, flush=True)
            if out_lines is not None:
                out_lines.append(line)
    return rows


def run_attention(out_lines=None):
    """Fused-template vs ref decode attention: achieved KV bytes from the
    SAME `StepCostModel` accounting the engine meters with (obs.cost), over
    a fixed synthetic decode (4 slots x 64 appended tokens). Deterministic
    pure-math rows, gated like the serving metrics.

    The load-bearing assertion (the paper's §4 kernel claim): the fused
    path's bytes carry NO dequantize round-trip — AMS planes are restored
    in VREGs, never materialized in HBM — and beat the ref gather on every
    scheme. ``dequant_kb`` is additionally gated at 0 in the baseline, so
    a future lowering that silently re-materializes pages fails CI."""
    from repro.cache.config import CacheConfig
    from repro.configs import get_config
    from repro.obs import build_cost_model

    cfg = get_config("qwen2-7b").reduced()
    cap, slots, steps = 64, 4, 64
    rows = []
    for kind in ("contiguous", "paged_bf16", "paged_ams"):
        ccfg = CacheConfig(kind=kind, page_size=8)
        if ccfg.paged:
            ccfg = ccfg.sized(capacity=cap, slots=slots)
        cm = build_cost_model(cfg, "fp16", ccfg)
        # causal floor of the trajectory: append token i+1, read i+1 keys
        floor = slots * sum(1 + (i + 1) for i in range(steps)) \
            * cm.kv_bytes_per_token
        impls = ("ref",) if kind == "contiguous" else ("ref", "pallas")
        per_impl = {}
        for impl in impls:
            kw = dict(cache_kind=ccfg.kind, impl=impl, capacity=cap,
                      page_size=ccfg.page_size,
                      max_pages=ccfg.max_pages_per_seq)
            ach = slots * sum(cm.achieved_kv_bytes(i, 1, **kw)
                              for i in range(steps))
            pos = slots * sum(
                1 + cm.achieved_kv_read_positions(i, 1, **kw)
                for i in range(steps))
            deq = ach - pos * cm.kv_bytes_per_token   # the HBM round-trip
            per_impl[impl] = (ach, deq)
            line = (f"kernel_attn/{kind}/{impl},0,"
                    f"kv_achieved_kb={ach / 1024:.1f} "
                    f"kv_vs_floor={ach / floor:.3f} "
                    f"dequant_kb={deq / 1024:.1f}")
            print(line, flush=True)
            if out_lines is not None:
                out_lines.append(line)
            rows.append((kind, impl, ach, deq))
        if "pallas" in per_impl:
            ach_f, deq_f = per_impl["pallas"]
            ach_r, deq_r = per_impl["ref"]
            assert deq_f == 0.0, (kind, deq_f)      # no HBM dequant, ever
            assert ach_f < ach_r, (kind, ach_f, ach_r)
            if ccfg.quantized:
                assert deq_r > 0.0                  # ref DOES round-trip
    return rows


if __name__ == "__main__":
    run()
    run_attention()
